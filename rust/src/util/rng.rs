//! Deterministic pseudo-random number generation (SplitMix64).
//!
//! Every stochastic component in the system (corpus generators, task
//! generators, workload arrival processes, property tests) threads one of
//! these through explicitly, so every experiment in EXPERIMENTS.md is
//! bit-reproducible from its seed.

#[derive(Debug, Clone)]
/// Deterministic splitmix64-based RNG (reproducible tests/benches).
pub struct Rng {
    state: u64,
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

impl Rng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
            spare: None,
        }
    }

    /// Derive an independent stream (for per-worker / per-sequence rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // multiply-shift; bias is negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// True with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Gaussian draw (Box-Muller).
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Uniform element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// Sample from an exponential distribution with the given rate
    /// (used by the serving workload's Poisson arrival process).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        -u.ln() / rate
    }

    /// Weighted index sample; weights need not be normalized.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(0);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 2);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let m = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.02, "{m}");
    }
}
