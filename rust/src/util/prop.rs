//! Property-based testing helper (proptest is not available offline).
//!
//! `check(cases, |rng| ...)` runs a property over many independently
//! seeded RNGs; on failure it reports the failing seed so the case can be
//! replayed with `check_seed`.  Generators live on `Rng` (util::rng) —
//! tests compose them inline, e.g. random cache traffic or random batch
//! plans.

use super::rng::Rng;

/// Run `prop` for `cases` deterministic seeds; panic with the seed on the
/// first failure.  Seeds derive from an env-overridable base so CI can
/// reproduce a failure exactly (`KVCAR_PROP_SEED=<seed>` pins a run).
pub fn check(cases: usize, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    if let Ok(pin) = std::env::var("KVCAR_PROP_SEED") {
        let seed: u64 = pin.parse().expect("KVCAR_PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (pinned seed {seed}): {msg}");
        }
        return;
    }
    let base = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case} (replay with KVCAR_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assertion helper returning `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |rng| {
            let n = rng.range(1, 100);
            prop_assert!(n >= 1 && n < 100, "n out of range: {n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_seed_report() {
        check(50, |rng| {
            let n = rng.below(10);
            prop_assert!(n < 5, "n = {n}");
            Ok(())
        });
    }
}
