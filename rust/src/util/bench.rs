//! Micro-benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets use this: warmup, fixed-duration measurement,
//! mean / p50 / p99 per iteration, throughput reporting, and a plain-text
//! row format that EXPERIMENTS.md quotes directly.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Optimization barrier (std `black_box` re-export).
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Warmup-then-measure micro-benchmark runner.
pub struct Bench {
    /// benchmark label
    pub name: String,
    warmup: Duration,
    measure: Duration,
    min_iters: usize,
}

#[derive(Debug, Clone)]
/// Timing summary of one benchmark.
pub struct Report {
    /// benchmark label
    pub name: String,
    /// measured iterations
    pub iters: usize,
    /// mean nanoseconds per iteration
    pub mean_ns: f64,
    /// median nanoseconds
    pub p50_ns: f64,
    /// 99th-percentile nanoseconds
    pub p99_ns: f64,
    /// fastest iteration
    pub min_ns: f64,
}

impl Report {
    /// Print the standard bench row.
    pub fn print(&self) {
        println!(
            "bench {:<44} iters={:<8} mean={:>12}  p50={:>12}  p99={:>12}  min={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }

    /// Print with derived throughput (elements or bytes per second).
    pub fn print_throughput(&self, units_per_iter: f64, unit: &str) {
        let per_sec = units_per_iter / (self.mean_ns * 1e-9);
        println!(
            "bench {:<44} iters={:<8} mean={:>12}  p50={:>12}  p99={:>12}  {:>12.3e} {}/s",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            per_sec,
            unit,
        );
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    /// Runner with env-tunable warmup/measure windows.
    pub fn new(name: &str) -> Self {
        // Env knobs let `make bench-fast` shrink runs during iteration.
        let ms = |k: &str, d: u64| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(ms("KVCAR_BENCH_WARMUP_MS", 200)),
            measure: Duration::from_millis(ms("KVCAR_BENCH_MEASURE_MS", 1000)),
            min_iters: 10,
        }
    }

    /// Override the measurement window.
    pub fn with_measure_ms(mut self, ms: u64) -> Self {
        self.measure = Duration::from_millis(ms);
        self
    }

    /// Run `f` repeatedly, timing each call.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Report {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // measure
        let mut samples: Vec<f64> = Vec::with_capacity(4096);
        let start = Instant::now();
        while start.elapsed() < self.measure || samples.len() < self.min_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
            if samples.len() >= 2_000_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        Report {
            name: self.name.clone(),
            iters: n,
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            p50_ns: samples[n / 2],
            p99_ns: samples[((n - 1) as f64 * 0.99) as usize],
            min_ns: samples[0],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = Bench::new("noop").with_measure_ms(20).run(|| 1 + 1);
        assert!(r.iters >= 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p50_ns <= r.p99_ns + 1.0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
