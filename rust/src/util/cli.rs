//! Tiny argv parser (clap is not available offline): subcommand + named
//! flags (`--key value` / `--key=value` / boolean `--flag`) + positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
/// Tiny `--key value` / `--flag` argv parser for bins and examples.
pub struct Args {
    /// first positional (subcommand)
    pub command: Option<String>,
    /// --key value pairs (bare flags record "true")
    pub flags: BTreeMap<String, String>,
    /// remaining positionals
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // --key value, unless the next token is another flag
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process argv.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String flag with default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// usize flag with default.
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// u64 flag with default.
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    /// f64 flag with default.
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    /// Presence flag (--faithful).
    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --model gpt2t --batch 8 --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.str("model", "x"), "gpt2t");
        assert_eq!(a.usize("batch", 1), 8);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn equals_form_and_positionals() {
        let a = parse("eval --lam=0.5 wikitext c4");
        assert_eq!(a.f64("lam", 0.0), 0.5);
        assert_eq!(a.positional, vec!["wikitext", "c4"]);
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.usize("steps", 100), 100);
        assert_eq!(a.str("model", "gpt2t"), "gpt2t");
        assert_eq!(a.opt("missing"), None);
    }

    #[test]
    fn flag_before_subcommand() {
        let a = parse("--artifacts art serve");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.str("artifacts", ""), "art");
    }
}
