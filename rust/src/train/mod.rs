//! Training driver: the paper's Algorithms 1 and 2 run *from rust* over
//! the AOT'd step artifacts.  Rust owns the loop, data stream, learning
//! schedule, stage sequencing, and checkpoints; python never runs.
//!
//! Stages (paper §IV-B):
//!
//! 1. `pretrain`        — base LM on the synthetic corpus (builds the
//!                        "pretrained model" Alg. 1 line 1 starts from).
//! 2. `ae_stage1`       — Alg. 1 lines 4-19: one layer at a time, one-hot
//!                        grad mask, CE + lambda*L1 reconstruction loss.
//! 3. `ae_stage2`       — Alg. 1 lines 22-26: joint finetune of the
//!                        selected layers' AEs.
//! 4. `analyze_heads`   — Alg. 2 lines 1-3: collect adjacent-layer head
//!                        L1 distances over evaluation batches.
//! 5. `reuse_finetune`  — Alg. 2 lines 4-18: finetune under fixed reuse
//!                        masks with the CE + scaled-L1 objective.

pub mod schedule;

use crate::compress::planner::RuntimeMasks;
use crate::compress::similarity::HeadDistances;
use crate::data::batch::lm_batch;
use crate::data::corpus::Corpus;
use crate::model::ModelSpec;
use crate::runtime::{Engine, Store, Tensor};
use anyhow::Result;
use std::time::Instant;

#[derive(Debug, Clone)]
/// Hyperparameters shared by every training stage.
pub struct TrainConfig {
    /// base learning rate
    pub lr: f32,
    /// aux-loss scale lambda (paper: "scaled by an empirical value")
    pub lam: f32,
    /// steps between loss log lines
    pub log_every: usize,
    /// print stage progress
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 3e-3,
            lam: 0.3,
            log_every: 25,
            verbose: true,
        }
    }
}

#[derive(Debug, Clone)]
/// Loss trajectory of one training stage.
pub struct StageLog {
    /// stage label
    pub stage: String,
    /// per-log-interval losses
    pub losses: Vec<f32>,
    /// stage wall-clock milliseconds
    pub wall_ms: u128,
}

impl StageLog {
    /// First logged loss (NaN when empty).
    pub fn first(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }
    /// Last logged loss (NaN when empty).
    pub fn last(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }
}

/// Drives Algorithms 1-2 from rust over the AOT train-step artifacts.
pub struct Trainer<'e> {
    /// PJRT runtime
    pub engine: &'e mut Engine,
    /// parameters + optimizer state threaded through steps
    pub store: Store,
    /// model dimensions
    pub spec: ModelSpec,
    /// model name prefix for artifact entries
    pub model: String,
    /// hyperparameters
    pub cfg: TrainConfig,
    /// completed stage logs
    pub logs: Vec<StageLog>,
}

impl<'e> Trainer<'e> {
    /// Load parameters and set up optimizer state for `model`.
    pub fn new(engine: &'e mut Engine, model: &str, cfg: TrainConfig) -> Result<Self> {
        let mut store = Store::new();
        engine.load_params(model, &mut store)?;
        let spec = ModelSpec::from_manifest(&engine.manifest.raw, model)?;
        Ok(Trainer {
            engine,
            store,
            spec,
            model: model.to_string(),
            cfg,
            logs: Vec::new(),
        })
    }

    /// Zero every optimizer-state / counter input of a step entry —
    /// called at stage boundaries (each stage owns a fresh Adam state).
    fn reset_opt_state(&mut self, entry: &str) -> Result<()> {
        let spec = self.engine.entry_spec(entry)?.clone();
        for io in &spec.inputs {
            if io.name.starts_with("m/") || io.name.starts_with("v/") || io.name == "step" {
                let t = match io.dtype {
                    crate::runtime::DType::F32 => Tensor::zeros_f32(io.shape.clone()),
                    crate::runtime::DType::I32 => Tensor::i32(
                        io.shape.clone(),
                        vec![0; io.shape.iter().product::<usize>().max(1)],
                    ),
                };
                self.store.insert(&io.name, t);
            }
        }
        Ok(())
    }

    fn push_batch(&mut self, corpus: &mut Corpus) {
        let (b, s) = (8, self.spec.max_seq);
        let tb = lm_batch(corpus, b, s);
        self.store.insert("tokens", Tensor::i32(vec![b, s], tb.tokens));
        self.store.insert("len_mask", Tensor::f32(vec![b, s], tb.mask));
    }

    fn run_stage(
        &mut self,
        entry: &str,
        stage: &str,
        corpus: &mut Corpus,
        steps: usize,
        lr: f32,
    ) -> Result<StageLog> {
        let t0 = Instant::now();
        self.store.insert("lr", Tensor::scalar_f32(lr));
        let mut losses = Vec::with_capacity(steps);
        for step in 0..steps {
            self.push_batch(corpus);
            self.engine.execute_into(entry, &mut self.store)?;
            let loss = self.store.get("loss")?.scalar_f32_value()?;
            losses.push(loss);
            if self.cfg.verbose && (step % self.cfg.log_every == 0 || step + 1 == steps) {
                println!("[{stage}] step {step:>4}  loss {loss:.4}");
            }
        }
        let log = StageLog {
            stage: stage.to_string(),
            losses,
            wall_ms: t0.elapsed().as_millis(),
        };
        self.logs.push(log.clone());
        Ok(log)
    }

    /// Stage 0: base-LM pretraining.
    pub fn pretrain(&mut self, corpus: &mut Corpus, steps: usize) -> Result<StageLog> {
        let entry = format!("{}_train_step", self.model);
        self.reset_opt_state(&entry)?;
        let lr = self.cfg.lr;
        self.run_stage(&entry, "pretrain", corpus, steps, lr)
    }

    fn push_gmask(&mut self, layers: &[usize]) {
        let l = self.spec.n_layer;
        let mut g = vec![0.0f32; l];
        for &i in layers {
            g[i] = 1.0;
        }
        self.store.insert("gmask", Tensor::f32(vec![l], g));
    }

    /// Alg. 1 stage 1: train each selected layer's AEs in isolation.
    pub fn ae_stage1(
        &mut self,
        corpus: &mut Corpus,
        layers: &[usize],
        steps_per_layer: usize,
    ) -> Result<Vec<StageLog>> {
        let entry = format!("{}_ae_train_step", self.model);
        self.store.insert("lam", Tensor::scalar_f32(self.cfg.lam));
        let mut out = Vec::new();
        for &layer in layers {
            self.reset_opt_state(&entry)?;
            self.push_gmask(&[layer]);
            let lr = self.cfg.lr;
            out.push(self.run_stage(
                &entry,
                &format!("ae_stage1[layer {layer}]"),
                corpus,
                steps_per_layer,
                lr,
            )?);
        }
        Ok(out)
    }

    /// Alg. 1 stage 2: joint finetune over the selected layer set.
    pub fn ae_stage2(
        &mut self,
        corpus: &mut Corpus,
        layers: &[usize],
        steps: usize,
    ) -> Result<StageLog> {
        let entry = format!("{}_ae_train_step", self.model);
        self.reset_opt_state(&entry)?;
        self.push_gmask(layers);
        self.store.insert("lam", Tensor::scalar_f32(self.cfg.lam));
        let lr = self.cfg.lr * 0.3; // gentler joint stage
        self.run_stage(&entry, "ae_stage2", corpus, steps, lr)
    }

    /// Alg. 2 lines 1-3: head similarity over `batches` eval batches.
    pub fn analyze_heads(&mut self, corpus: &mut Corpus, batches: usize) -> Result<HeadDistances> {
        let entry = format!("{}_kv_stats", self.model);
        let mut hd = HeadDistances::new(self.spec.n_layer, self.spec.n_kv_head);
        for _ in 0..batches {
            self.push_batch(corpus);
            let out = self.engine.execute(&entry, &self.store)?;
            hd.accumulate(out[0].1.as_f32()?, out[1].1.as_f32()?);
        }
        Ok(hd.finalize())
    }

    /// Alg. 2 lines 4-18: finetune under fixed masks.
    pub fn reuse_finetune(
        &mut self,
        corpus: &mut Corpus,
        masks: &RuntimeMasks,
        steps: usize,
    ) -> Result<StageLog> {
        let entry = format!("{}_reuse_ft_step", self.model);
        self.reset_opt_state(&entry)?;
        self.apply_masks(masks);
        self.store.insert("lam", Tensor::scalar_f32(self.cfg.lam));
        let lr = self.cfg.lr * 0.3;
        self.run_stage(&entry, "reuse_ft", corpus, steps, lr)
    }

    /// Install the plan's runtime mask tensors into the store.
    pub fn apply_masks(&mut self, masks: &RuntimeMasks) {
        let (l, h) = (self.spec.n_layer, self.spec.n_kv_head);
        self.store
            .insert("compress", Tensor::f32(vec![l], masks.compress.clone()));
        self.store
            .insert("reuse_k", Tensor::f32(vec![l, h], masks.reuse_k.clone()));
        self.store
            .insert("reuse_v", Tensor::f32(vec![l, h], masks.reuse_v.clone()));
        self.store.insert("quant", Tensor::scalar_f32(masks.quant));
    }

    /// Checkpoint base + AE params in the shared binary format.
    pub fn checkpoint(&self, dir: &std::path::Path, tag: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let bin = dir.join(format!("{}_{tag}.bin", self.model));
        let idx = dir.join(format!("{}_{tag}.json", self.model));
        self.store.save_params(&bin, &idx, &["base/", "ae/"])?;
        Ok(())
    }

    /// Reload a checkpoint written by `checkpoint`.
    pub fn restore(&mut self, dir: &std::path::Path, tag: &str) -> Result<usize> {
        let bin = dir.join(format!("{}_{tag}.bin", self.model));
        let idx = dir.join(format!("{}_{tag}.json", self.model));
        self.store.load_params(&bin, &idx)
    }
}
