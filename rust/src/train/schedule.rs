//! Learning-rate schedules for the training driver.
//!
//! The AOT'd step artifacts take `lr` as a runtime scalar, so schedules
//! live entirely in rust.  Linear warmup + cosine decay is the default
//! for pretraining; the AE and reuse stages use constant-with-warmup
//! (short stages at small step counts — paper §IV-B keeps these simple).

#[derive(Debug, Clone, Copy, PartialEq)]
/// Learning-rate schedule families used by the training stages.
pub enum Schedule {
    /// fixed rate
    Constant {
        /// the fixed rate
        lr: f32,
    },
    /// linear warmup then cosine decay to a floor
    WarmupCosine {
        /// rate at the end of warmup
        peak_lr: f32,
        /// floor as a fraction of peak (e.g. 0.1)
        min_frac: f32,
        /// linear warmup steps
        warmup_steps: usize,
        /// steps the cosine decays over
        total_steps: usize,
    },
    /// linear warmup then fixed rate
    WarmupConstant {
        /// rate after warmup
        lr: f32,
        /// warmup steps before the constant rate
        warmup_steps: usize,
    },
}

impl Schedule {
    /// Learning rate at a global step.
    pub fn lr_at(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::WarmupConstant { lr, warmup_steps } => {
                if warmup_steps == 0 || step >= warmup_steps {
                    lr
                } else {
                    lr * (step + 1) as f32 / warmup_steps as f32
                }
            }
            Schedule::WarmupCosine {
                peak_lr,
                min_frac,
                warmup_steps,
                total_steps,
            } => {
                if step < warmup_steps {
                    return peak_lr * (step + 1) as f32 / warmup_steps.max(1) as f32;
                }
                let t = (step - warmup_steps) as f32
                    / (total_steps.saturating_sub(warmup_steps)).max(1) as f32;
                let t = t.clamp(0.0, 1.0);
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                peak_lr * (min_frac + (1.0 - min_frac) * cos)
            }
        }
    }

    /// Default pretraining schedule for `total` steps.
    pub fn pretrain_default(peak_lr: f32, total: usize) -> Schedule {
        Schedule::WarmupCosine {
            peak_lr,
            min_frac: 0.1,
            warmup_steps: (total / 20).max(5).min(total),
            total_steps: total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 1e-3 };
        assert_eq!(s.lr_at(0), 1e-3);
        assert_eq!(s.lr_at(10_000), 1e-3);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::WarmupConstant {
            lr: 1.0,
            warmup_steps: 10,
        };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.lr_at(10), 1.0);
        assert_eq!(s.lr_at(99), 1.0);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = Schedule::WarmupCosine {
            peak_lr: 1.0,
            min_frac: 0.1,
            warmup_steps: 10,
            total_steps: 110,
        };
        // peak right after warmup
        assert!((s.lr_at(10) - 1.0).abs() < 1e-3);
        // floor at the end
        assert!((s.lr_at(110) - 0.1).abs() < 1e-3);
        assert!((s.lr_at(10_000) - 0.1).abs() < 1e-3);
        // monotone decreasing after warmup
        let mut prev = f32::INFINITY;
        for step in 10..110 {
            let lr = s.lr_at(step);
            assert!(lr <= prev + 1e-6);
            prev = lr;
        }
    }

    #[test]
    fn pretrain_default_sane() {
        let s = Schedule::pretrain_default(3e-3, 300);
        assert!(s.lr_at(0) > 0.0);
        assert!(s.lr_at(0) < 3e-3);
        assert!(s.lr_at(299) < 1e-3);
    }
}
