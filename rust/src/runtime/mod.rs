//! PJRT runtime: manifest + params loading, HLO-text compilation, and
//! named-tensor execution of the AOT artifacts.

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod mock;
pub mod residency;
pub mod store;
pub mod tensor;

pub use backend::ExecBackend;
pub use engine::{Engine, EngineStats, EntryTraffic};
pub use manifest::{DType, EntrySpec, IoSpec, Manifest};
pub use mock::MockEngine;
pub use residency::{BufferCache, DeviceBackend, MirrorBackend};
pub use store::Store;
pub use tensor::Tensor;

use std::path::PathBuf;

/// Default artifacts directory: $KVCAR_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("KVCAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
