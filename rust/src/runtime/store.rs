//! Named tensor store: parameters + optimizer state + step counters, the
//! mutable state the training driver and serving engine thread through
//! artifact calls.
//!
//! Binary format shared with `python/compile/params.py`: `params.bin` is
//! concatenated little-endian f32 buffers; `params.json` indexes them by
//! name/shape/offset.  Rust checkpoints use the identical format, so a
//! rust-trained model can be reloaded by python tests and vice versa.

use super::tensor::Tensor;
use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::Path;

/// Cap on tracked spans per region: one slot's writes are a handful of
/// contiguous runs, so a log this deep means something unusual — rather
/// than grow unboundedly, the log collapses to its bounding span (a
/// sound over-approximation; the engine just uploads more).
const MAX_DIRTY_SPANS: usize = 512;

#[derive(Debug, Clone, Default)]
/// Dirty-write log of one resident region (the `DirtyRanges` record
/// behind [`Store::note_region_writes`] / [`Store::take_region_writes`]).
struct DirtyLog {
    /// store version up to which `spans` is a complete cover of writes;
    /// a consumer whose last-seen version predates this cannot trust the
    /// log and must re-upload the whole region
    base: u64,
    /// sorted, disjoint element spans written since `base`
    spans: Vec<(usize, usize)>,
    /// the region was opened raw (`resident_region`) and no write has
    /// been declared since — the slice may have been mutated anywhere,
    /// so the log is untrusted until `note_region_writes` runs
    pending: bool,
}

impl DirtyLog {
    /// Forget everything: spans are complete-and-empty as of `version`.
    fn invalidate(&mut self, version: u64) {
        self.base = version;
        self.spans.clear();
        self.pending = false;
    }

    /// Record one element span, keeping `spans` sorted and disjoint
    /// (overlapping/adjacent spans merge).
    fn push(&mut self, start: usize, end: usize) {
        if start >= end {
            return;
        }
        let i = self.spans.partition_point(|s| s.1 < start);
        let mut j = i;
        let (mut a, mut b) = (start, end);
        while j < self.spans.len() && self.spans[j].0 <= b {
            a = a.min(self.spans[j].0);
            b = b.max(self.spans[j].1);
            j += 1;
        }
        self.spans.splice(i..j, [(a, b)]);
        if self.spans.len() > MAX_DIRTY_SPANS {
            let lo = self.spans[0].0;
            let hi = self.spans[self.spans.len() - 1].1;
            self.spans.clear();
            self.spans.push((lo, hi));
        }
    }
}

#[derive(Debug, Clone, Default)]
/// Versioned named-tensor map (parameters, optimizer state, staging).
pub struct Store {
    map: BTreeMap<String, Tensor>,
    /// monotone per-tensor versions: the engine's device-buffer cache
    /// re-uploads an input only when its version changed since the last
    /// call (parameters stay resident across thousands of steps)
    versions: BTreeMap<String, u64>,
    /// names currently registered as persistent regions
    /// (`resident_region`).  While a name is registered the plain
    /// staging entry points (`insert`, `insert_view`, `insert_view_i32`,
    /// `get_mut`) refuse it — a per-round `insert_view` on a live
    /// resident region would silently alias (or drop) the buffer that
    /// slot-resident state lives in.
    resident: BTreeSet<String>,
    /// monotone per-region epochs: an epoch bumps when the region's
    /// backing allocation is replaced **or** when the name is
    /// re-registered after a `release_region` (the contents may have
    /// been rewritten while unprotected).  Epochs survive release, so
    /// owners can always detect invalidation as `epoch != last_seen`.
    region_epochs: BTreeMap<String, u64>,
    /// per-region dirty-span logs backing the engine's delta uploads.
    /// In a `RefCell` because the engine consumes spans through the
    /// shared `&Store` it executes against (single-threaded; the store
    /// is not `Sync` and is never shared across threads).
    region_writes: RefCell<BTreeMap<String, DirtyLog>>,
    counter: u64,
}

impl Store {
    /// Empty store.
    pub fn new() -> Store {
        Store::default()
    }

    fn assert_not_resident(&self, name: &str, op: &str) {
        assert!(
            !self.resident.contains(name),
            "store tensor '{name}' is a live resident region: `{op}` would silently \
             alias or replace its slot-resident buffer — go through `resident_region` \
             (or `release_region` first)"
        );
    }

    /// Bump the tensor's version and return the new value.
    fn bump(&mut self, name: &str) -> u64 {
        self.counter += 1;
        self.versions.insert(name.to_string(), self.counter);
        self.counter
    }

    /// Version bump for the *untracked* write paths (plain inserts,
    /// `get_mut`): any lingering dirty log — the name may have been a
    /// resident region before a `release_region` — can no longer cover
    /// this write, so it is invalidated wholesale.
    fn bump_invalidate(&mut self, name: &str) {
        let v = self.bump(name);
        if let Some(log) = self.region_writes.get_mut().get_mut(name) {
            log.invalidate(v);
        }
    }

    /// Insert or replace a tensor (version bumped).  Panics on a live
    /// resident region (see [`Store::resident_region`]).
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.assert_not_resident(name, "insert");
        self.bump_invalidate(name);
        self.map.insert(name.to_string(), t);
    }

    /// Insert-or-overwrite an f32 tensor in place, reusing the existing
    /// allocation when the element count matches — the per-step staging
    /// path (latents, k/v cache inputs) writes into the resident buffer
    /// instead of allocating a fresh `Vec` every round.  Returns the
    /// tensor's mutable data sized to `shape`; contents are the previous
    /// values on reuse (callers overwrite) and zeros on (re)allocation.
    /// The version is bumped either way so the engine re-uploads.
    /// Panics on a live resident region (see [`Store::resident_region`]).
    pub fn insert_view(&mut self, name: &str, shape: Vec<usize>) -> &mut [f32] {
        self.assert_not_resident(name, "insert_view");
        let n: usize = shape.iter().product();
        self.bump_invalidate(name);
        let t = self
            .map
            .entry(name.to_string())
            .or_insert_with(|| Tensor::zeros_f32(shape.clone()));
        match t {
            Tensor::F32 { shape: sh, data } if data.len() == n => {
                *sh = shape;
                data
            }
            other => {
                *other = Tensor::zeros_f32(shape);
                match other {
                    Tensor::F32 { data, .. } => data,
                    _ => unreachable!(),
                }
            }
        }
    }

    /// `insert_view` for i32 tensors (token/pos staging).
    /// Panics on a live resident region (see [`Store::resident_region`]).
    pub fn insert_view_i32(&mut self, name: &str, shape: Vec<usize>) -> &mut [i32] {
        self.assert_not_resident(name, "insert_view_i32");
        let n: usize = shape.iter().product();
        self.bump_invalidate(name);
        let make = |shape: Vec<usize>| Tensor::I32 {
            data: vec![0; shape.iter().product()],
            shape,
        };
        let t = self
            .map
            .entry(name.to_string())
            .or_insert_with(|| make(shape.clone()));
        match t {
            Tensor::I32 { shape: sh, data } if data.len() == n => {
                *sh = shape;
                data
            }
            other => {
                *other = make(shape);
                match other {
                    Tensor::I32 { data, .. } => data,
                    _ => unreachable!(),
                }
            }
        }
    }

    /// [`Store::insert_view`] with guaranteed-zero contents: reused
    /// allocations are memset before being returned, so callers that
    /// write a sparse subset (wave packing, padded staging) never leak
    /// a previous round's values into the padding.
    pub fn insert_view_zeroed(&mut self, name: &str, shape: Vec<usize>) -> &mut [f32] {
        let d = self.insert_view(name, shape);
        d.fill(0.0);
        d
    }

    /// [`Store::insert_view_i32`] with guaranteed-zero contents.
    pub fn insert_view_i32_zeroed(&mut self, name: &str, shape: Vec<usize>) -> &mut [i32] {
        let d = self.insert_view_i32(name, shape);
        d.fill(0);
        d
    }

    /// Register (or re-open) a **persistent resident f32 region** and
    /// return `(data, fresh)`.
    ///
    /// Unlike [`Store::insert_view`] — which is per-round staging that
    /// callers fully overwrite — a resident region's *contents persist
    /// between calls*: the decode loop keeps the effective k/v cache in
    /// it and writes only the rows that changed.  Guarantees:
    ///
    /// * same element count → the backing allocation is **reused** and
    ///   the previous contents are intact (`fresh == false`);
    /// * count changed or the name is new → a zeroed allocation replaces
    ///   it, the region **epoch** bumps (`fresh == true`), and the owner
    ///   must rebuild everything it kept there;
    /// * re-registering after `release_region` also bumps the epoch even
    ///   when the allocation survived — the contents may have been
    ///   rewritten while the name was unprotected, so owners must treat
    ///   them as untrusted;
    /// * the tensor version bumps on every call (the engine must look at
    ///   the region again — contents are presumed mutated through the
    ///   returned slice);
    /// * writes through the returned slice **should be declared** with
    ///   [`Store::note_region_writes`] afterwards: the store cannot see
    ///   raw slice writes, so declared spans are what lets the engine
    ///   upload only dirty chunks.  An open with no declaration is safe
    ///   but slow — the dirty log is marked untrusted and the engine
    ///   falls back to re-uploading the whole region;
    /// * while registered, `insert`/`insert_view`/`insert_view_i32` on
    ///   the same name panic instead of silently aliasing the region.
    pub fn resident_region(&mut self, name: &str, shape: Vec<usize>) -> (&mut [f32], bool) {
        let n: usize = shape.iter().product();
        let v = self.bump(name);
        let fresh = !matches!(
            self.map.get(name),
            Some(Tensor::F32 { data, .. }) if data.len() == n
        );
        // newly registered = not in the protected set before this call:
        // either brand new, or re-registered after a `release_region`
        // (the contents may have been rewritten while unprotected) —
        // both invalidate whatever an owner kept here, like a realloc
        let newly_registered = self.resident.insert(name.to_string());
        if fresh || newly_registered {
            let epoch = self.region_epochs.entry(name.to_string()).or_insert(0);
            *epoch += 1;
        }
        {
            let logs = self.region_writes.get_mut();
            let log = logs.entry(name.to_string()).or_default();
            if fresh || newly_registered {
                log.invalidate(v);
            }
            // untrusted until the caller declares its writes
            log.pending = true;
        }
        if fresh {
            self.map.insert(name.to_string(), Tensor::zeros_f32(shape));
            match self.map.get_mut(name).unwrap() {
                Tensor::F32 { data, .. } => (data.as_mut_slice(), true),
                _ => unreachable!(),
            }
        } else {
            match self.map.get_mut(name).unwrap() {
                Tensor::F32 { shape: sh, data } => {
                    *sh = shape;
                    (data.as_mut_slice(), false)
                }
                _ => unreachable!(),
            }
        }
    }

    /// Epoch of a resident region (0 = never registered).  Monotone: it
    /// bumps when the backing allocation is replaced or when the name is
    /// re-registered after a release, and it survives `release_region` —
    /// so `epoch != last_seen` is always a sound invalidation check.
    pub fn region_epoch(&self, name: &str) -> u64 {
        self.region_epochs.get(name).copied().unwrap_or(0)
    }

    /// Unregister a resident region: the tensor stays in the store but
    /// loses its aliasing protection (plain inserts work again).  The
    /// dirty log is marked untrusted — anything can write the tensor
    /// while unprotected, so consumers fall back to a full upload.
    pub fn release_region(&mut self, name: &str) {
        self.resident.remove(name);
        let v = self.version(name);
        if let Some(log) = self.region_writes.get_mut().get_mut(name) {
            log.invalidate(v);
            log.pending = true;
        }
    }

    /// Whether `name` is currently registered as a resident region.
    pub fn is_resident_region(&self, name: &str) -> bool {
        self.resident.contains(name)
    }

    /// Declare the element spans written through the slice returned by
    /// [`Store::resident_region`] since it was last opened.  Spans may
    /// over-approximate (extra elements just get re-uploaded) but must
    /// *cover* every write — the store cannot observe raw slice writes,
    /// and an uncovered write would leave the engine's device copy
    /// stale.  Declaring (even an empty span list) marks the open as
    /// accounted for; opens that are never declared degrade the next
    /// [`Store::take_region_writes`] to `None` (full upload).
    ///
    /// Panics when `name` is not a live resident region.
    pub fn note_region_writes(&mut self, name: &str, spans: &[(usize, usize)]) {
        assert!(
            self.resident.contains(name),
            "note_region_writes('{name}'): not a live resident region"
        );
        let log = self
            .region_writes
            .get_mut()
            .get_mut(name)
            .expect("live resident region always has a dirty log");
        for &(a, b) in spans {
            log.push(a, b);
        }
        log.pending = false;
    }

    /// Consume the dirty element spans of a resident region accumulated
    /// since `since_version` (the consumer's last-seen [`Store::version`]
    /// of the tensor).  Returns `None` when the log cannot prove
    /// coverage — the consumer lapsed past an invalidation (epoch bump,
    /// release, untracked insert) or the region was opened without a
    /// [`Store::note_region_writes`] declaration — in which case the
    /// caller must re-upload the whole region.  Either way the log
    /// resets to "complete and empty as of the current version", so a
    /// single engine consuming every round sees exactly the writes of
    /// that round.  Spans are sorted and disjoint.
    pub fn take_region_writes(
        &self,
        name: &str,
        since_version: u64,
    ) -> Option<Vec<(usize, usize)>> {
        let cur = self.version(name);
        let mut logs = self.region_writes.borrow_mut();
        let log = logs.get_mut(name)?;
        if log.pending || since_version < log.base {
            log.invalidate(cur);
            return None;
        }
        log.base = cur;
        Some(std::mem::take(&mut log.spans))
    }

    /// Read-only peek at a resident region's pending dirty spans
    /// without consuming the log (the consumer's `take_region_writes`
    /// cursor is unaffected).  `None` when the region has no
    /// coverage-complete log.  Inspection hook for the scenario
    /// harness: pending spans must always be sorted, disjoint, and
    /// in-bounds for the region.
    pub fn region_spans(&self, name: &str) -> Option<Vec<(usize, usize)>> {
        let logs = self.region_writes.borrow();
        let log = logs.get(name)?;
        if log.pending {
            return None;
        }
        Some(log.spans.clone())
    }

    /// Version of a tensor (0 = absent). Bumped on every insert.
    pub fn version(&self, name: &str) -> u64 {
        self.versions.get(name).copied().unwrap_or(0)
    }

    /// Tensor by name (error names the missing tensor).
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("store has no tensor '{name}'"))
    }

    /// Mutable tensor by name (version bumped conservatively).  Panics
    /// on a live resident region — the returned `&mut Tensor` could
    /// replace the region's backing allocation wholesale.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.assert_not_resident(name, "get_mut");
        // conservatively bump: the caller may mutate through this borrow
        self.bump_invalidate(name);
        self.map
            .get_mut(name)
            .ok_or_else(|| anyhow!("store has no tensor '{name}'"))
    }

    /// Whether a tensor exists.
    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    /// Every tensor name, sorted.
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the store holds nothing.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Names with the given prefix (e.g. all of "base/", "ae/").
    pub fn with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a String> {
        self.map.keys().filter(move |k| k.starts_with(prefix))
    }

    /// Load `params.bin` + `params.json` into the store.
    pub fn load_params(&mut self, bin: &Path, index: &Path) -> Result<usize> {
        let idx_text = std::fs::read_to_string(index)
            .with_context(|| format!("reading {index:?}"))?;
        let idx = Json::parse(&idx_text)?;
        let bytes = std::fs::read(bin).with_context(|| format!("reading {bin:?}"))?;
        let total = idx
            .get("total_bytes")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("params index missing total_bytes"))?;
        anyhow::ensure!(bytes.len() == total, "params.bin size mismatch");
        let entries = idx
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("params index missing params"))?;
        let mut count = 0;
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("param missing name"))?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("param {name} missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = e
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("param {name} missing offset"))?;
            let n: usize = shape.iter().product();
            anyhow::ensure!(offset + n * 4 <= bytes.len(), "param {name} out of range");
            let data: Vec<f32> = bytes[offset..offset + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            self.insert(name, Tensor::f32(shape, data));
            count += 1;
        }
        Ok(count)
    }

    /// Save every f32 tensor matching `prefixes` in the shared format.
    pub fn save_params(&self, bin: &Path, index: &Path, prefixes: &[&str]) -> Result<()> {
        let mut entries: Vec<Json> = Vec::new();
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(bin).with_context(|| format!("creating {bin:?}"))?,
        );
        let mut offset = 0usize;
        for (name, t) in &self.map {
            if !prefixes.iter().any(|p| name.starts_with(p)) {
                continue;
            }
            let data = t.as_f32()?;
            for v in data {
                file.write_all(&v.to_le_bytes())?;
            }
            entries.push(json::obj(vec![
                ("name", json::s(name)),
                (
                    "shape",
                    json::arr(t.shape().iter().map(|&d| json::num(d as f64))),
                ),
                ("offset", json::num(offset as f64)),
            ]));
            offset += data.len() * 4;
        }
        file.flush()?;
        let idx = json::obj(vec![
            ("total_bytes", json::num(offset as f64)),
            ("params", Json::Arr(entries)),
        ]);
        std::fs::write(index, idx.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kvcar_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = Store::new();
        s.insert("base/wq", Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        s.insert("ae/k/enc/w1", Tensor::f32(vec![3], vec![-1.0, 0.5, 9.0]));
        s.insert("m/base/wq", Tensor::zeros_f32(vec![2, 2])); // excluded
        let bin = dir.join("p.bin");
        let idx = dir.join("p.json");
        s.save_params(&bin, &idx, &["base/", "ae/"]).unwrap();

        let mut s2 = Store::new();
        let n = s2.load_params(&bin, &idx).unwrap();
        assert_eq!(n, 2);
        assert_eq!(s2.get("base/wq").unwrap(), s.get("base/wq").unwrap());
        assert_eq!(s2.get("ae/k/enc/w1").unwrap(), s.get("ae/k/enc/w1").unwrap());
        assert!(s2.get("m/base/wq").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefix_iteration() {
        let mut s = Store::new();
        s.insert("base/a", Tensor::scalar_f32(1.0));
        s.insert("base/b", Tensor::scalar_f32(2.0));
        s.insert("ae/c", Tensor::scalar_f32(3.0));
        assert_eq!(s.with_prefix("base/").count(), 2);
        assert_eq!(s.with_prefix("ae/").count(), 1);
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let s = Store::new();
        let e = s.get("nope").unwrap_err();
        assert!(e.to_string().contains("nope"));
    }

    #[test]
    fn insert_view_reuses_allocation_and_bumps_version() {
        let mut s = Store::new();
        let v0 = s.version("stage");
        let ptr0 = {
            let d = s.insert_view("stage", vec![2, 3]);
            assert_eq!(d.len(), 6);
            assert!(d.iter().all(|&x| x == 0.0)); // fresh: zeroed
            d.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            d.as_ptr()
        };
        let v1 = s.version("stage");
        assert!(v1 > v0);
        // same element count, different shape: allocation is reused
        let ptr1 = {
            let d = s.insert_view("stage", vec![6]);
            assert_eq!(d.len(), 6);
            assert_eq!(d[0], 1.0); // previous contents (caller overwrites)
            d.as_ptr()
        };
        assert_eq!(ptr0, ptr1, "same-size overwrite must not reallocate");
        assert_eq!(s.get("stage").unwrap().shape(), &[6]);
        assert!(s.version("stage") > v1);
        // different element count: reallocates and zeroes
        let d = s.insert_view("stage", vec![4]);
        assert_eq!(d, [0.0; 4]);
    }

    #[test]
    fn insert_view_zeroed_clears_reused_allocations() {
        let mut s = Store::new();
        s.insert_view("stage", vec![4]).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let d = s.insert_view_zeroed("stage", vec![4]);
        assert_eq!(d, [0.0; 4], "reuse must not leak previous contents");
        s.insert_view_i32("toks", vec![3]).copy_from_slice(&[7, 8, 9]);
        let d = s.insert_view_i32_zeroed("toks", vec![3]);
        assert_eq!(d, [0i32; 3]);
    }

    #[test]
    fn resident_region_persists_contents_and_tracks_epoch() {
        let mut s = Store::new();
        assert_eq!(s.region_epoch("r"), 0);
        let ptr0 = {
            let (d, fresh) = s.resident_region("r", vec![2, 3]);
            assert!(fresh, "first registration allocates");
            assert!(d.iter().all(|&x| x == 0.0));
            d[4] = 7.5;
            d.as_ptr()
        };
        let e1 = s.region_epoch("r");
        assert_eq!(e1, 1);
        let v1 = s.version("r");
        // same element count: contents and allocation persist
        let ptr1 = {
            let (d, fresh) = s.resident_region("r", vec![6]);
            assert!(!fresh, "same-size reopen must not reallocate");
            assert_eq!(d[4], 7.5, "resident contents must persist");
            d.as_ptr()
        };
        assert_eq!(ptr0, ptr1);
        assert_eq!(s.region_epoch("r"), e1, "epoch stable across reuse");
        assert!(s.version("r") > v1, "version must bump (engine re-upload)");
        // size change: fresh zeroed allocation, epoch bumps
        let (d, fresh) = s.resident_region("r", vec![4]);
        assert!(fresh);
        assert_eq!(d, [0.0; 4]);
        assert_eq!(s.region_epoch("r"), e1 + 1);
    }

    #[test]
    #[should_panic(expected = "live resident region")]
    fn insert_view_on_resident_region_panics() {
        let mut s = Store::new();
        s.resident_region("k_cache", vec![4]);
        s.insert_view("k_cache", vec![4]); // must panic, not alias
    }

    #[test]
    fn release_region_restores_plain_staging_and_lapse_bumps_epoch() {
        let mut s = Store::new();
        s.resident_region("x", vec![2]);
        assert_eq!(s.region_epoch("x"), 1);
        s.release_region("x");
        assert_eq!(s.region_epoch("x"), 1, "epoch must survive release");
        let d = s.insert_view("x", vec![2]); // no panic after release
        assert_eq!(d.len(), 2);
        // re-registration after a lapse: same-size allocation survives
        // (fresh == false) but the epoch must bump — the contents were
        // writable while unprotected, so owners must invalidate
        let (_, fresh) = s.resident_region("x", vec![2]);
        assert!(!fresh, "same-size re-registration reuses the allocation");
        assert_eq!(s.region_epoch("x"), 2, "lapsed re-registration must bump");
        // steady re-opens while registered never bump
        s.resident_region("x", vec![2]);
        assert_eq!(s.region_epoch("x"), 2);
    }

    #[test]
    fn declared_writes_flow_to_consumer_once() {
        let mut s = Store::new();
        s.resident_region("r", vec![16]);
        // a consumer that never saw the region must full-upload first
        assert_eq!(s.take_region_writes("r", 0), None, "never-synced consumer");
        s.resident_region("r", vec![16]);
        let v1 = s.version("r");
        s.note_region_writes("r", &[(2, 5), (4, 9), (12, 14)]);
        // overlapping declarations merge, sorted and disjoint
        assert_eq!(s.take_region_writes("r", v1), Some(vec![(2, 9), (12, 14)]));
        // consumed: a consumer current at `v1` now sees an empty delta
        assert_eq!(s.take_region_writes("r", v1), Some(vec![]));
        // next round: reopen + declare, only the new spans come back
        s.resident_region("r", vec![16]);
        s.note_region_writes("r", &[(0, 2)]);
        assert_eq!(s.take_region_writes("r", v1), Some(vec![(0, 2)]));
    }

    #[test]
    fn undeclared_open_degrades_to_full_upload() {
        let mut s = Store::new();
        s.resident_region("r", vec![8]);
        let v = s.version("r");
        s.note_region_writes("r", &[(0, 8)]);
        assert!(s.take_region_writes("r", v).is_some());
        // open without declaring: raw slice writes are invisible, so the
        // log must refuse to vouch for the delta
        s.resident_region("r", vec![8]);
        assert_eq!(s.take_region_writes("r", v), None, "undeclared open");
        // the refusal resets the log; a disciplined round works again
        let v = s.version("r");
        s.resident_region("r", vec![8]);
        s.note_region_writes("r", &[(1, 3)]);
        assert_eq!(s.take_region_writes("r", v), Some(vec![(1, 3)]));
    }

    #[test]
    fn realloc_release_and_plain_inserts_invalidate_the_log() {
        let mut s = Store::new();
        s.resident_region("r", vec![8]);
        let v = s.version("r");
        s.note_region_writes("r", &[(0, 8)]);
        assert!(s.take_region_writes("r", v).is_some());
        // realloc (size change, epoch bump) wipes the spans
        s.resident_region("r", vec![12]);
        s.note_region_writes("r", &[(0, 1)]);
        assert_eq!(s.take_region_writes("r", v), None, "epoch bump");
        // release marks the log untrusted even before any write
        let v = s.version("r");
        s.release_region("r");
        assert_eq!(s.take_region_writes("r", v), None, "released region");
        // a plain insert_view while unprotected stays invalidated after
        // re-registration (epoch bump) — no stale span can survive
        s.insert_view("r", vec![12]);
        let v = s.version("r");
        s.resident_region("r", vec![12]);
        s.note_region_writes("r", &[(3, 4)]);
        assert_eq!(s.take_region_writes("r", v), None, "lapsed consumer");
    }

    #[test]
    fn multi_round_spans_accumulate_for_a_slow_consumer() {
        let mut s = Store::new();
        s.resident_region("r", vec![8]);
        let v0 = s.version("r");
        s.note_region_writes("r", &[(0, 8)]);
        assert!(s.take_region_writes("r", v0).is_some());
        // an unknown name has no log at all
        assert_eq!(s.take_region_writes("never", 0), None, "unknown name");
        // two rounds of declared writes, no consumption in between
        s.resident_region("r", vec![8]);
        s.note_region_writes("r", &[(1, 2)]);
        s.resident_region("r", vec![8]);
        s.note_region_writes("r", &[(5, 6)]);
        // consumer current at v0 gets both rounds' spans in one delta
        assert_eq!(s.take_region_writes("r", v0), Some(vec![(1, 2), (5, 6)]));
    }

    #[test]
    fn span_log_caps_to_bounding_box() {
        let mut s = Store::new();
        s.resident_region("r", vec![4096]);
        let v = s.version("r");
        let spans: Vec<(usize, usize)> =
            (0..MAX_DIRTY_SPANS + 1).map(|i| (3 * i, 3 * i + 1)).collect();
        s.note_region_writes("r", &spans);
        let got = s.take_region_writes("r", v).unwrap();
        assert_eq!(got, vec![(0, 3 * MAX_DIRTY_SPANS + 1)], "collapsed, still covering");
    }

    #[test]
    fn insert_view_replaces_other_dtype() {
        let mut s = Store::new();
        s.insert("x", Tensor::i32(vec![2], vec![7, 8]));
        let d = s.insert_view("x", vec![2]);
        assert_eq!(d, [0.0; 2]);
        let d = s.insert_view_i32("x", vec![3]);
        assert_eq!(d, [0i32; 3]);
        d[1] = 5;
        assert_eq!(s.get("x").unwrap().as_i32().unwrap(), &[0, 5, 0]);
    }
}
