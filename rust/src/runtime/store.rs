//! Named tensor store: parameters + optimizer state + step counters, the
//! mutable state the training driver and serving engine thread through
//! artifact calls.
//!
//! Binary format shared with `python/compile/params.py`: `params.bin` is
//! concatenated little-endian f32 buffers; `params.json` indexes them by
//! name/shape/offset.  Rust checkpoints use the identical format, so a
//! rust-trained model can be reloaded by python tests and vice versa.

use super::tensor::Tensor;
use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

#[derive(Debug, Clone, Default)]
/// Versioned named-tensor map (parameters, optimizer state, staging).
pub struct Store {
    map: BTreeMap<String, Tensor>,
    /// monotone per-tensor versions: the engine's device-buffer cache
    /// re-uploads an input only when its version changed since the last
    /// call (parameters stay resident across thousands of steps)
    versions: BTreeMap<String, u64>,
    counter: u64,
}

impl Store {
    /// Empty store.
    pub fn new() -> Store {
        Store::default()
    }

    /// Insert or replace a tensor (version bumped).
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.counter += 1;
        self.versions.insert(name.to_string(), self.counter);
        self.map.insert(name.to_string(), t);
    }

    /// Insert-or-overwrite an f32 tensor in place, reusing the existing
    /// allocation when the element count matches — the per-step staging
    /// path (latents, k/v cache inputs) writes into the resident buffer
    /// instead of allocating a fresh `Vec` every round.  Returns the
    /// tensor's mutable data sized to `shape`; contents are the previous
    /// values on reuse (callers overwrite) and zeros on (re)allocation.
    /// The version is bumped either way so the engine re-uploads.
    pub fn insert_view(&mut self, name: &str, shape: Vec<usize>) -> &mut [f32] {
        let n: usize = shape.iter().product();
        self.counter += 1;
        self.versions.insert(name.to_string(), self.counter);
        let t = self
            .map
            .entry(name.to_string())
            .or_insert_with(|| Tensor::zeros_f32(shape.clone()));
        match t {
            Tensor::F32 { shape: sh, data } if data.len() == n => {
                *sh = shape;
                data
            }
            other => {
                *other = Tensor::zeros_f32(shape);
                match other {
                    Tensor::F32 { data, .. } => data,
                    _ => unreachable!(),
                }
            }
        }
    }

    /// `insert_view` for i32 tensors (token/pos staging).
    pub fn insert_view_i32(&mut self, name: &str, shape: Vec<usize>) -> &mut [i32] {
        let n: usize = shape.iter().product();
        self.counter += 1;
        self.versions.insert(name.to_string(), self.counter);
        let make = |shape: Vec<usize>| Tensor::I32 {
            data: vec![0; shape.iter().product()],
            shape,
        };
        let t = self
            .map
            .entry(name.to_string())
            .or_insert_with(|| make(shape.clone()));
        match t {
            Tensor::I32 { shape: sh, data } if data.len() == n => {
                *sh = shape;
                data
            }
            other => {
                *other = make(shape);
                match other {
                    Tensor::I32 { data, .. } => data,
                    _ => unreachable!(),
                }
            }
        }
    }

    /// Version of a tensor (0 = absent). Bumped on every insert.
    pub fn version(&self, name: &str) -> u64 {
        self.versions.get(name).copied().unwrap_or(0)
    }

    /// Tensor by name (error names the missing tensor).
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("store has no tensor '{name}'"))
    }

    /// Mutable tensor by name (version bumped conservatively).
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        // conservatively bump: the caller may mutate through this borrow
        self.counter += 1;
        self.versions.insert(name.to_string(), self.counter);
        self.map
            .get_mut(name)
            .ok_or_else(|| anyhow!("store has no tensor '{name}'"))
    }

    /// Whether a tensor exists.
    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    /// Every tensor name, sorted.
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the store holds nothing.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Names with the given prefix (e.g. all of "base/", "ae/").
    pub fn with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a String> {
        self.map.keys().filter(move |k| k.starts_with(prefix))
    }

    /// Load `params.bin` + `params.json` into the store.
    pub fn load_params(&mut self, bin: &Path, index: &Path) -> Result<usize> {
        let idx_text = std::fs::read_to_string(index)
            .with_context(|| format!("reading {index:?}"))?;
        let idx = Json::parse(&idx_text)?;
        let bytes = std::fs::read(bin).with_context(|| format!("reading {bin:?}"))?;
        let total = idx
            .get("total_bytes")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("params index missing total_bytes"))?;
        anyhow::ensure!(bytes.len() == total, "params.bin size mismatch");
        let entries = idx
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("params index missing params"))?;
        let mut count = 0;
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("param missing name"))?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("param {name} missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = e
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("param {name} missing offset"))?;
            let n: usize = shape.iter().product();
            anyhow::ensure!(offset + n * 4 <= bytes.len(), "param {name} out of range");
            let data: Vec<f32> = bytes[offset..offset + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            self.insert(name, Tensor::f32(shape, data));
            count += 1;
        }
        Ok(count)
    }

    /// Save every f32 tensor matching `prefixes` in the shared format.
    pub fn save_params(&self, bin: &Path, index: &Path, prefixes: &[&str]) -> Result<()> {
        let mut entries: Vec<Json> = Vec::new();
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(bin).with_context(|| format!("creating {bin:?}"))?,
        );
        let mut offset = 0usize;
        for (name, t) in &self.map {
            if !prefixes.iter().any(|p| name.starts_with(p)) {
                continue;
            }
            let data = t.as_f32()?;
            for v in data {
                file.write_all(&v.to_le_bytes())?;
            }
            entries.push(json::obj(vec![
                ("name", json::s(name)),
                (
                    "shape",
                    json::arr(t.shape().iter().map(|&d| json::num(d as f64))),
                ),
                ("offset", json::num(offset as f64)),
            ]));
            offset += data.len() * 4;
        }
        file.flush()?;
        let idx = json::obj(vec![
            ("total_bytes", json::num(offset as f64)),
            ("params", Json::Arr(entries)),
        ]);
        std::fs::write(index, idx.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kvcar_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = Store::new();
        s.insert("base/wq", Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        s.insert("ae/k/enc/w1", Tensor::f32(vec![3], vec![-1.0, 0.5, 9.0]));
        s.insert("m/base/wq", Tensor::zeros_f32(vec![2, 2])); // excluded
        let bin = dir.join("p.bin");
        let idx = dir.join("p.json");
        s.save_params(&bin, &idx, &["base/", "ae/"]).unwrap();

        let mut s2 = Store::new();
        let n = s2.load_params(&bin, &idx).unwrap();
        assert_eq!(n, 2);
        assert_eq!(s2.get("base/wq").unwrap(), s.get("base/wq").unwrap());
        assert_eq!(s2.get("ae/k/enc/w1").unwrap(), s.get("ae/k/enc/w1").unwrap());
        assert!(s2.get("m/base/wq").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefix_iteration() {
        let mut s = Store::new();
        s.insert("base/a", Tensor::scalar_f32(1.0));
        s.insert("base/b", Tensor::scalar_f32(2.0));
        s.insert("ae/c", Tensor::scalar_f32(3.0));
        assert_eq!(s.with_prefix("base/").count(), 2);
        assert_eq!(s.with_prefix("ae/").count(), 1);
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let s = Store::new();
        let e = s.get("nope").unwrap_err();
        assert!(e.to_string().contains("nope"));
    }

    #[test]
    fn insert_view_reuses_allocation_and_bumps_version() {
        let mut s = Store::new();
        let v0 = s.version("stage");
        let ptr0 = {
            let d = s.insert_view("stage", vec![2, 3]);
            assert_eq!(d.len(), 6);
            assert!(d.iter().all(|&x| x == 0.0)); // fresh: zeroed
            d.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            d.as_ptr()
        };
        let v1 = s.version("stage");
        assert!(v1 > v0);
        // same element count, different shape: allocation is reused
        let ptr1 = {
            let d = s.insert_view("stage", vec![6]);
            assert_eq!(d.len(), 6);
            assert_eq!(d[0], 1.0); // previous contents (caller overwrites)
            d.as_ptr()
        };
        assert_eq!(ptr0, ptr1, "same-size overwrite must not reallocate");
        assert_eq!(s.get("stage").unwrap().shape(), &[6]);
        assert!(s.version("stage") > v1);
        // different element count: reallocates and zeroes
        let d = s.insert_view("stage", vec![4]);
        assert_eq!(d, [0.0; 4]);
    }

    #[test]
    fn insert_view_replaces_other_dtype() {
        let mut s = Store::new();
        s.insert("x", Tensor::i32(vec![2], vec![7, 8]));
        let d = s.insert_view("x", vec![2]);
        assert_eq!(d, [0.0; 2]);
        let d = s.insert_view_i32("x", vec![3]);
        assert_eq!(d, [0i32; 3]);
        d[1] = 5;
        assert_eq!(s.get("x").unwrap().as_i32().unwrap(), &[0, 5, 0]);
    }
}
