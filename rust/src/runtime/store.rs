//! Named tensor store: parameters + optimizer state + step counters, the
//! mutable state the training driver and serving engine thread through
//! artifact calls.
//!
//! Binary format shared with `python/compile/params.py`: `params.bin` is
//! concatenated little-endian f32 buffers; `params.json` indexes them by
//! name/shape/offset.  Rust checkpoints use the identical format, so a
//! rust-trained model can be reloaded by python tests and vice versa.

use super::tensor::Tensor;
use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct Store {
    map: BTreeMap<String, Tensor>,
    /// monotone per-tensor versions: the engine's device-buffer cache
    /// re-uploads an input only when its version changed since the last
    /// call (parameters stay resident across thousands of steps)
    versions: BTreeMap<String, u64>,
    counter: u64,
}

impl Store {
    pub fn new() -> Store {
        Store::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.counter += 1;
        self.versions.insert(name.to_string(), self.counter);
        self.map.insert(name.to_string(), t);
    }

    /// Version of a tensor (0 = absent). Bumped on every insert.
    pub fn version(&self, name: &str) -> u64 {
        self.versions.get(name).copied().unwrap_or(0)
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("store has no tensor '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        // conservatively bump: the caller may mutate through this borrow
        self.counter += 1;
        self.versions.insert(name.to_string(), self.counter);
        self.map
            .get_mut(name)
            .ok_or_else(|| anyhow!("store has no tensor '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Names with the given prefix (e.g. all of "base/", "ae/").
    pub fn with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a String> {
        self.map.keys().filter(move |k| k.starts_with(prefix))
    }

    /// Load `params.bin` + `params.json` into the store.
    pub fn load_params(&mut self, bin: &Path, index: &Path) -> Result<usize> {
        let idx_text = std::fs::read_to_string(index)
            .with_context(|| format!("reading {index:?}"))?;
        let idx = Json::parse(&idx_text)?;
        let bytes = std::fs::read(bin).with_context(|| format!("reading {bin:?}"))?;
        let total = idx
            .get("total_bytes")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("params index missing total_bytes"))?;
        anyhow::ensure!(bytes.len() == total, "params.bin size mismatch");
        let entries = idx
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("params index missing params"))?;
        let mut count = 0;
        for e in entries {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("param missing name"))?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("param {name} missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = e
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("param {name} missing offset"))?;
            let n: usize = shape.iter().product();
            anyhow::ensure!(offset + n * 4 <= bytes.len(), "param {name} out of range");
            let data: Vec<f32> = bytes[offset..offset + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            self.insert(name, Tensor::f32(shape, data));
            count += 1;
        }
        Ok(count)
    }

    /// Save every f32 tensor matching `prefixes` in the shared format.
    pub fn save_params(&self, bin: &Path, index: &Path, prefixes: &[&str]) -> Result<()> {
        let mut entries: Vec<Json> = Vec::new();
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(bin).with_context(|| format!("creating {bin:?}"))?,
        );
        let mut offset = 0usize;
        for (name, t) in &self.map {
            if !prefixes.iter().any(|p| name.starts_with(p)) {
                continue;
            }
            let data = t.as_f32()?;
            for v in data {
                file.write_all(&v.to_le_bytes())?;
            }
            entries.push(json::obj(vec![
                ("name", json::s(name)),
                (
                    "shape",
                    json::arr(t.shape().iter().map(|&d| json::num(d as f64))),
                ),
                ("offset", json::num(offset as f64)),
            ]));
            offset += data.len() * 4;
        }
        file.flush()?;
        let idx = json::obj(vec![
            ("total_bytes", json::num(offset as f64)),
            ("params", Json::Arr(entries)),
        ]);
        std::fs::write(index, idx.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("kvcar_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = Store::new();
        s.insert("base/wq", Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        s.insert("ae/k/enc/w1", Tensor::f32(vec![3], vec![-1.0, 0.5, 9.0]));
        s.insert("m/base/wq", Tensor::zeros_f32(vec![2, 2])); // excluded
        let bin = dir.join("p.bin");
        let idx = dir.join("p.json");
        s.save_params(&bin, &idx, &["base/", "ae/"]).unwrap();

        let mut s2 = Store::new();
        let n = s2.load_params(&bin, &idx).unwrap();
        assert_eq!(n, 2);
        assert_eq!(s2.get("base/wq").unwrap(), s.get("base/wq").unwrap());
        assert_eq!(s2.get("ae/k/enc/w1").unwrap(), s.get("ae/k/enc/w1").unwrap());
        assert!(s2.get("m/base/wq").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefix_iteration() {
        let mut s = Store::new();
        s.insert("base/a", Tensor::scalar_f32(1.0));
        s.insert("base/b", Tensor::scalar_f32(2.0));
        s.insert("ae/c", Tensor::scalar_f32(3.0));
        assert_eq!(s.with_prefix("base/").count(), 2);
        assert_eq!(s.with_prefix("ae/").count(), 1);
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let s = Store::new();
        let e = s.get("nope").unwrap_err();
        assert!(e.to_string().contains("nope"));
    }
}
