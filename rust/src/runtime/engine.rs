//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them with named inputs from a `Store`.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and aot.py).
//!
//! Entry points were lowered with `return_tuple=True`, so execution
//! returns one tuple literal that is decomposed and mapped back to the
//! manifest's output names.

use super::manifest::{DType, EntrySpec, Manifest};
use super::residency::{chunk_rows_from_env, BufferCache, DeviceBackend};
use super::store::Store;
use super::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::time::Instant;

/// Compile-once PJRT executor for the AOT HLO artifacts.
pub struct Engine {
    /// the L2<->L3 contract (entry points + flattened I/O)
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// per-entry device-resident input buffers keyed by store version:
    /// an input is re-uploaded only when its tensor changed since the
    /// previous call, so parameters (the bulk of every signature) stay
    /// on device across thousands of steps; store-resident regions
    /// additionally delta-upload only their dirty chunks (residency.rs,
    /// DESIGN.md §7).  EXPERIMENTS.md §Perf L3.
    buffer_cache: BufferCache<xla::PjRtBuffer>,
    /// disable to fall back to literal-per-call execution (perf A/B)
    pub use_buffer_cache: bool,
    /// keep store-resident regions device-resident between rounds,
    /// consuming the store's dirty-span log to upload only changed
    /// chunks.  Disable (`KVCAR_NO_DEVICE_RESIDENCY`, or
    /// `ServeConfig::device_residency = false`) to force the legacy
    /// whole-buffer re-upload every round — the bitwise reference path.
    pub use_device_residency: bool,
    /// rows per delta-upload chunk (`KVCAR_RESIDENT_CHUNK_ROWS`)
    pub chunk_rows: usize,
    /// armed launch faults, keyed by kind (`"prefill"` / `"decode"`):
    /// (launches until it fires, re-arms left after firing).  The real
    /// engine honors the same `inject_launch_fault` contract as the
    /// mock, so fault drills and the chaos scenario matrix run against
    /// live artifacts too; a fault fires *before* anything is compiled
    /// or uploaded, leaving device state untouched.
    launch_faults: HashMap<String, (u64, u64)>,
    /// compile/execute/traffic counters
    pub stats: EngineStats,
}

#[derive(Debug, Default, Clone)]
/// Launch and host<->device traffic counters.
pub struct EngineStats {
    /// entry points compiled (once each)
    pub compiles: u64,
    /// artifact calls issued — the launch-count law the batched
    /// faithful decode is asserted against
    pub executions: u64,
    /// nanoseconds spent in XLA compilation
    pub compile_ns: u128,
    /// nanoseconds spent executing
    pub execute_ns: u128,
    /// host->device traffic in elements actually moved (delta uploads
    /// count only the elements they patch)
    pub input_elements: u64,
    /// elements fetched back per call
    pub output_elements: u64,
    /// buffered path: inputs re-uploaded because their store version
    /// changed (staging traffic) vs served from the device-resident cache
    pub input_uploads: u64,
    /// inputs served from the device-resident cache
    pub input_cache_hits: u64,
    /// exact host->device bytes moved (f32/i32 aware; delta uploads
    /// count only patched chunks)
    pub input_bytes: u64,
    /// exact device->host bytes fetched back
    pub output_bytes: u64,
    /// bytes moved for store-resident region inputs (delta or full)
    pub resident_bytes_uploaded: u64,
    /// resident-region bytes that did NOT move: cache hits plus the
    /// clean remainder of delta rounds — the savings the device-resident
    /// cache exists for
    pub resident_bytes_skipped: u64,
    /// resident-region inputs that fell back to a whole-buffer upload
    /// (no prior buffer, span log couldn't vouch, or the binding can't
    /// patch in place)
    pub full_uploads: u64,
    /// stale device buffers dropped because their region realloc'd or
    /// was released (buffer-cache lifetime sweep)
    pub buffers_evicted: u64,
    /// per-entry traffic breakdown (keyed by entry-point name)
    pub per_entry: BTreeMap<String, EntryTraffic>,
}

#[derive(Debug, Default, Clone)]
/// Per-entry-point slice of the traffic counters.
pub struct EntryTraffic {
    /// calls of this entry
    pub executions: u64,
    /// host->device bytes moved for this entry's inputs
    pub input_bytes: u64,
    /// device->host bytes fetched from this entry's outputs
    pub output_bytes: u64,
    /// resident-region bytes moved (delta or full) for this entry
    pub resident_bytes_uploaded: u64,
    /// resident-region bytes this entry avoided moving
    pub resident_bytes_skipped: u64,
    /// whole-buffer fallback uploads of resident regions
    pub full_uploads: u64,
}

impl EngineStats {
    /// Per-entry traffic row (created on first touch).
    pub fn entry_mut(&mut self, entry: &str) -> &mut EntryTraffic {
        self.per_entry.entry(entry.to_string()).or_default()
    }
}

/// [`DeviceBackend`] over the PJRT client: whole-tensor uploads via
/// `buffer_from_host_buffer`.  The xla binding exposes no host->device
/// sub-buffer write, so `patch_f32` reports unsupported and resident
/// regions fall back to full uploads (counted in
/// [`EngineStats::full_uploads`]); a device-side dynamic-update-slice
/// patch kernel is the ROADMAP path to honoring deltas here.
struct PjrtBackend<'a> {
    client: &'a xla::PjRtClient,
}

impl DeviceBackend for PjrtBackend<'_> {
    type Buf = xla::PjRtBuffer;

    fn upload(&mut self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        match t {
            Tensor::F32 { shape, data } => self.client.buffer_from_host_buffer(data, shape, None),
            Tensor::I32 { shape, data } => self.client.buffer_from_host_buffer(data, shape, None),
        }
        .map_err(|e| anyhow!("uploading buffer: {e:?}"))
    }

    fn patch_f32(
        &mut self,
        _buf: &mut xla::PjRtBuffer,
        _at: usize,
        _data: &[f32],
    ) -> Result<bool> {
        Ok(false)
    }
}

impl Engine {
    /// Load the manifest and open a CPU PJRT client.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine {
            manifest,
            client,
            executables: HashMap::new(),
            buffer_cache: BufferCache::new(),
            use_buffer_cache: std::env::var("KVCAR_NO_BUFFER_CACHE").is_err(),
            use_device_residency: std::env::var("KVCAR_NO_DEVICE_RESIDENCY").is_err(),
            chunk_rows: chunk_rows_from_env(),
            launch_faults: HashMap::new(),
            stats: EngineStats::default(),
        })
    }

    /// Arm a launch fault: the `nth` (1-based) next prefill /
    /// decode-step launch fails before compilation or upload, then
    /// re-arms `burst` more times.  Returns whether `kind` is one the
    /// engine can fault (`"prefill"` / `"decode"`).
    pub fn arm_launch_fault(&mut self, kind: &str, nth: u64, burst: u64) -> bool {
        if kind != "prefill" && kind != "decode" {
            return false;
        }
        self.launch_faults
            .insert(kind.to_string(), (nth.max(1), burst));
        true
    }

    /// Fire an armed launch fault if `entry` is its kind's due launch.
    /// Checked before [`Engine::load`] so a faulted launch costs no
    /// compile and moves no bytes — the same pre-execution contract the
    /// mock implements, which the scheduler's transactional rollback
    /// relies on.
    fn tick_launch_fault(&mut self, entry: &str) -> Result<()> {
        let kind = if entry.contains("_prefill") {
            "prefill"
        } else if entry.contains("_decode_step") {
            "decode"
        } else {
            return Ok(());
        };
        let Some((n, burst)) = self.launch_faults.get_mut(kind) else {
            return Ok(());
        };
        if *n > 1 {
            *n -= 1;
            return Ok(());
        }
        if *burst > 0 {
            *burst -= 1;
            *n = 1;
        } else {
            self.launch_faults.remove(kind);
        }
        anyhow::bail!("injected {kind} launch fault before launching {entry}")
    }

    /// Compile (or fetch the cached) executable for an entry point.
    pub fn load(&mut self, entry: &str) -> Result<()> {
        if self.executables.contains_key(entry) {
            return Ok(());
        }
        let spec = self.manifest.entry(entry)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {:?}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {entry}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.compile_ns += t0.elapsed().as_nanos();
        self.executables.insert(entry.to_string(), exe);
        Ok(())
    }

    /// Load the model's parameters into the store (base/* and ae/*).
    pub fn load_params(&self, model: &str, store: &mut Store) -> Result<usize> {
        store.load_params(
            &self.manifest.params_bin(model)?,
            &self.manifest.params_index(model)?,
        )
    }

    /// Execute `entry` reading inputs by name from the store; returns
    /// outputs keyed by the manifest's output names.
    pub fn execute(&mut self, entry: &str, store: &Store) -> Result<Vec<(String, Tensor)>> {
        self.tick_launch_fault(entry)?;
        self.load(entry)?;
        let spec = self.manifest.entry(entry)?.clone();
        let result = if self.use_buffer_cache {
            self.execute_buffered(entry, &spec, store)?
        } else {
            let mut literals = Vec::with_capacity(spec.inputs.len());
            for io in &spec.inputs {
                let t = store
                    .get(&io.name)
                    .with_context(|| format!("assembling inputs for {entry}"))?;
                check_io(io, t).with_context(|| format!("input {} of {entry}", io.name))?;
                self.stats.input_elements += t.len() as u64;
                self.stats.input_bytes += t.byte_len() as u64;
                self.stats.entry_mut(entry).input_bytes += t.byte_len() as u64;
                literals.push(t.to_literal()?);
            }
            let exe = self.executables.get(entry).unwrap();
            let t0 = Instant::now();
            let r = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {entry}: {e:?}"))?;
            self.stats.execute_ns += t0.elapsed().as_nanos();
            r
        };
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {entry}: {e:?}"))?;
        self.stats.executions += 1;
        self.stats.entry_mut(entry).executions += 1;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("decomposing result of {entry}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{entry}: {} outputs, manifest says {}",
            parts.len(),
            spec.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (io, lit) in spec.outputs.iter().zip(parts) {
            let t = Tensor::from_literal(&lit)
                .with_context(|| format!("output {} of {entry}", io.name))?;
            check_io(io, &t).with_context(|| format!("output {} of {entry}", io.name))?;
            self.stats.output_elements += t.len() as u64;
            self.stats.output_bytes += t.byte_len() as u64;
            self.stats.entry_mut(entry).output_bytes += t.byte_len() as u64;
            out.push((io.name.clone(), t));
        }
        Ok(out)
    }

    /// Buffered execution: inputs become persistent device-resident
    /// PjRtBuffers, re-uploaded only when the store version changed;
    /// store-resident regions (the effective k/v cache) delta-upload
    /// only their dirty chunks when the backend supports patching.
    fn execute_buffered(
        &mut self,
        entry: &str,
        spec: &EntrySpec,
        store: &Store,
    ) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        // drop buffers whose region realloc'd or was released before
        // they can pin dead device allocations through this call
        self.stats.buffers_evicted += self.buffer_cache.sweep_stale(store);
        self.buffer_cache.ensure_entry(entry, spec.inputs.len());
        let mut dev = PjrtBackend {
            client: &self.client,
        };
        for (i, io) in spec.inputs.iter().enumerate() {
            let t = store
                .get(&io.name)
                .with_context(|| format!("assembling inputs for {entry}"))?;
            check_io(io, t).with_context(|| format!("input {} of {entry}", io.name))?;
            self.buffer_cache
                .sync_input(
                    &mut dev,
                    entry,
                    i,
                    io,
                    t,
                    store,
                    self.use_device_residency,
                    self.chunk_rows,
                    &mut self.stats,
                )
                .with_context(|| format!("uploading {} for {entry}", io.name))?;
        }
        let bufs = self.buffer_cache.buffers(entry)?;
        let exe = self.executables.get(entry).unwrap();
        let t0 = Instant::now();
        let r = exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("executing {entry}: {e:?}"))?;
        self.stats.execute_ns += t0.elapsed().as_nanos();
        Ok(r)
    }

    /// Execute and write outputs back into the store (training steps:
    /// outputs are named like their input counterparts).
    pub fn execute_into(&mut self, entry: &str, store: &mut Store) -> Result<()> {
        for (name, t) in self.execute(entry, store)? {
            store.insert(&name, t);
        }
        Ok(())
    }

    /// Manifest spec of one entry point.
    pub fn entry_spec(&self, entry: &str) -> Result<&EntrySpec> {
        self.manifest.entry(entry)
    }

    /// Initialize zero tensors for every input of `entry` with the given
    /// prefix (optimizer state `m/`, `v/`, counters).
    pub fn init_zeros(&self, entry: &str, prefix: &str, store: &mut Store) -> Result<()> {
        for io in &self.manifest.entry(entry)?.inputs {
            if io.name.starts_with(prefix) && !store.contains(&io.name) {
                let t = match io.dtype {
                    DType::F32 => Tensor::zeros_f32(io.shape.clone()),
                    DType::I32 => Tensor::i32(
                        io.shape.clone(),
                        vec![0; io.shape.iter().product::<usize>().max(1)],
                    ),
                };
                store.insert(&io.name, t);
            }
        }
        Ok(())
    }
}

fn check_io(io: &super::manifest::IoSpec, t: &Tensor) -> Result<()> {
    let dtype_ok = matches!(
        (&io.dtype, t),
        (DType::F32, Tensor::F32 { .. }) | (DType::I32, Tensor::I32 { .. })
    );
    anyhow::ensure!(
        dtype_ok,
        "dtype mismatch: manifest {:?}, tensor {}",
        io.dtype,
        t.dtype_name()
    );
    anyhow::ensure!(
        io.shape == t.shape(),
        "shape mismatch: manifest {:?}, tensor {:?}",
        io.shape,
        t.shape()
    );
    Ok(())
}
