//! AOT manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.  Describes every entry point's HLO file and its
//! flattened input/output tensors (name, shape, dtype, in call order).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq, Eq)]
/// Element type of a manifest tensor.
pub enum DType {
    /// 32-bit float
    F32,
    /// 32-bit signed integer
    I32,
}

#[derive(Debug, Clone)]
/// One flattened input/output tensor of an entry point.
pub struct IoSpec {
    /// flattened name (e.g. "base/wq", "k_lat")
    pub name: String,
    /// dense row-major shape
    pub shape: Vec<usize>,
    /// element type
    pub dtype: DType,
}

impl IoSpec {
    /// Element count of the shape.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
/// One entry point: its HLO file plus I/O in call order.
pub struct EntrySpec {
    /// entry-point name
    pub name: String,
    /// HLO text file path
    pub file: PathBuf,
    /// inputs in call order
    pub inputs: Vec<IoSpec>,
    /// outputs in tuple order
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
/// Parsed artifacts/manifest.json.
pub struct Manifest {
    /// artifact directory
    pub dir: PathBuf,
    /// model names present
    pub models: Vec<String>,
    /// entry points by name
    pub entries: BTreeMap<String, EntrySpec>,
    /// the raw JSON (model hyperparameters etc.)
    pub raw: Json,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("io missing name"))?
        .to_string();
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("io {name} missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match j.get("dtype").and_then(Json::as_str) {
        Some("float32") => DType::F32,
        Some("int32") => DType::I32,
        other => return Err(anyhow!("io {name}: unsupported dtype {other:?}")),
    };
    Ok(IoSpec { name, shape, dtype })
}

impl Manifest {
    /// Parse `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let raw = Json::parse(&text).context("parsing manifest.json")?;
        let models = raw
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
            .keys()
            .cloned()
            .collect();
        let mut entries = BTreeMap::new();
        for (name, e) in raw
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let file = dir.join(
                e.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry {name} missing file"))?,
            );
            let parse_list = |key: &str| -> Result<Vec<IoSpec>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry {name} missing {key}"))?
                    .iter()
                    .map(parse_io)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file,
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            entries,
            raw,
        })
    }

    /// Entry spec by name (error names the missing entry).
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("entry '{name}' not in manifest"))
    }

    /// Path of a model's parameter buffer file.
    pub fn params_bin(&self, model: &str) -> Result<PathBuf> {
        let f = self
            .raw
            .get("models")
            .and_then(|m| m.get(model))
            .and_then(|m| m.get("params_bin"))
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("model {model} missing params_bin"))?;
        Ok(self.dir.join(f))
    }

    /// Path of a model's parameter index file.
    pub fn params_index(&self, model: &str) -> Result<PathBuf> {
        let f = self
            .raw
            .get("models")
            .and_then(|m| m.get(model))
            .and_then(|m| m.get("params_index"))
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("model {model} missing params_index"))?;
        Ok(self.dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_io_spec() {
        let j = Json::parse(r#"{"name":"base/wq","shape":[8,128,128],"dtype":"float32"}"#)
            .unwrap();
        let io = parse_io(&j).unwrap();
        assert_eq!(io.name, "base/wq");
        assert_eq!(io.elements(), 8 * 128 * 128);
        assert_eq!(io.dtype, DType::F32);
    }

    #[test]
    fn rejects_unknown_dtype() {
        let j = Json::parse(r#"{"name":"x","shape":[1],"dtype":"float64"}"#).unwrap();
        assert!(parse_io(&j).is_err());
    }

    #[test]
    fn load_missing_dir_hints_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
