//! Host-side tensor values and Literal conversion.

use anyhow::{anyhow, bail, Result};
use xla::Literal;

#[derive(Debug, Clone, PartialEq)]
/// A host tensor in one of the two artifact dtypes.
pub enum Tensor {
    /// dense row-major f32
    F32 { shape: Vec<usize>, data: Vec<f32> },
    /// dense row-major i32
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    /// F32 tensor (shape must cover `data`).
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape, data }
    }

    /// I32 tensor (shape must cover `data`).
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape, data }
    }

    /// Zeroed f32 tensor.
    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::F32 {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Rank-0 f32 tensor.
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    /// Rank-0 i32 tensor.
    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32 {
            shape: vec![],
            data: vec![v],
        }
    }

    /// The shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    /// Whether the tensor has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes per element of this tensor's dtype.
    pub fn dtype_bytes(&self) -> usize {
        match self {
            // both artifact dtypes are 32-bit today; keep the seam so
            // traffic accounting stays byte-accurate if f16 lands
            Tensor::F32 { .. } | Tensor::I32 { .. } => 4,
        }
    }

    /// Exact host-memory payload size in bytes (traffic accounting).
    pub fn byte_len(&self) -> usize {
        self.len() * self.dtype_bytes()
    }

    /// Serialize the payload as little-endian bytes (the on-device
    /// layout PJRT uploads; test/bench device mirrors compare against
    /// this for bitwise equality).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        match self {
            Tensor::F32 { data, .. } => data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            Tensor::I32 { data, .. } => data.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    /// "f32" or "i32" (error messages).
    pub fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "float32",
            Tensor::I32 { .. } => "int32",
        }
    }

    /// Borrow f32 data (error on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Mutably borrow f32 data (error on dtype mismatch).
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Borrow i32 data (error on dtype mismatch).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// The single value of a rank-0 f32 tensor.
    pub fn scalar_f32_value(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal for execution.
    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => Literal::vec1(data),
            Tensor::I32 { data, .. } => Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert back from an XLA literal.
    pub fn from_literal(lit: &Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => Err(anyhow!("unsupported literal element type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = Tensor::scalar_i32(42);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.shape(), &[] as &[usize]);
        assert_eq!(back.as_i32().unwrap(), &[42]);
    }

    #[test]
    fn byte_len_and_le_bytes_are_exact() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, -2.0, 0.5, 3.0]);
        assert_eq!(t.byte_len(), 16);
        let bytes = t.to_le_bytes();
        assert_eq!(bytes.len(), 16);
        assert_eq!(&bytes[0..4], &1.0f32.to_le_bytes());
        assert_eq!(&bytes[4..8], &(-2.0f32).to_le_bytes());
        let t = Tensor::i32(vec![3], vec![7, -1, 0]);
        assert_eq!(t.byte_len(), 12);
        assert_eq!(&t.to_le_bytes()[4..8], &(-1i32).to_le_bytes());
    }

    #[test]
    fn type_mismatch_errors() {
        let t = Tensor::scalar_f32(1.0);
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }
}
