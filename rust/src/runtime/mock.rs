//! Deterministic in-process mock of the artifact runtime.
//!
//! [`MockEngine`] implements [`ExecBackend`] with closed-form tensors —
//! no artifacts, no device, no wall clock — so the whole serving stack
//! (wave admission, prefix sharing, resident staging, faithful
//! reconstruction, park/resume) runs end-to-end in unit tests and the
//! scenario harness.  The numeric recipes deliberately mirror the
//! coordinator's existing pure mocks:
//!
//! * prefill entries reproduce `LaneWiseMockPrefiller` bitwise (same
//!   `val` hash per element), so a mock-backed `ServingEngine` produces
//!   exactly the tensors the wave-prefill tests pin;
//! * `{m}_decode_kv*` entries reproduce `RowWiseMockDecoder` bitwise;
//! * `{m}_decode_step_b{B}` derives each slot's new rows from the same
//!   `val` hash keyed on (token, position), and perturbs its logits
//!   with a digest of the slot's *staged* `k_cache`/`v_cache` rows —
//!   a staging bug (wrong slot, missed sync, stale epoch) changes the
//!   sampled token stream instead of passing silently.
//!
//! The mock also honors the store's resident-region protocol: it drains
//! dirty-span logs for `k_cache`/`v_cache` like the real engine and
//! accounts uploaded/skipped bytes, so device-residency metrics and the
//! `KVCAR_NO_DEVICE_RESIDENCY` leg behave the same way under test.

use super::backend::ExecBackend;
use super::engine::EngineStats;
use super::store::Store;
use super::tensor::Tensor;
use crate::model::ModelSpec;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Deterministic artifact-free execution backend (see module docs).
pub struct MockEngine {
    spec: ModelSpec,
    decode_batches: Vec<usize>,
    /// compiled lane capacity of `{m}_prefill_b`; `None` simulates an
    /// artifact set without the batched entry
    prefill_capacity: Option<usize>,
    /// compiled batch capacity of `{m}_decode_kv_bt`
    kv_bt_capacity: Option<usize>,
    /// whether the token-granular `{m}_decode_kv_t` entry exists
    granular_decode_kv: bool,
    device_residency: bool,
    stats: EngineStats,
    /// one-shot prefill-launch fault: fails the nth next prefill call
    fail_prefill_in: Option<u64>,
    /// one-shot decode-launch fault: fails the nth next decode_step call
    fail_decode_in: Option<u64>,
    /// re-arms left on the prefill fault after it fires (flapping lane)
    burst_prefill: u64,
    /// re-arms left on the decode fault after it fires (flapping lane)
    burst_decode: u64,
    /// last-seen store versions of resident regions (dirty-span drain)
    last_versions: BTreeMap<String, u64>,
}

impl MockEngine {
    /// Mock runtime for `spec` with the full entry ladder: batched
    /// prefill (capacity 8), decode rungs `[1, 2, 4, 8]`, and all three
    /// latent-decoder entries.
    pub fn new(spec: ModelSpec) -> MockEngine {
        MockEngine {
            spec,
            decode_batches: vec![1, 2, 4, 8],
            prefill_capacity: Some(8),
            kv_bt_capacity: Some(8),
            granular_decode_kv: true,
            device_residency: true,
            stats: EngineStats::default(),
            fail_prefill_in: None,
            fail_decode_in: None,
            burst_prefill: 0,
            burst_decode: 0,
            last_versions: BTreeMap::new(),
        }
    }

    /// Same per-element hash as `LaneWiseMockPrefiller::val` — the two
    /// must agree bitwise (pinned by a unit test below) so mock-backed
    /// serving and the wave-prefill tests pin identical tensors.
    fn val(tag: u32, byte: u8, layer: usize, t: usize, j: usize) -> f32 {
        let h = tag
            .wrapping_mul(0x9E37)
            .wrapping_add(byte as u32 * 131)
            .wrapping_add(layer as u32 * 31)
            .wrapping_add(t as u32 * 7)
            .wrapping_add(j as u32);
        ((h % 2003) as f32 - 1001.0) / 257.0
    }

    /// Same per-row map as `RowWiseMockDecoder::decode_rows`.
    fn decode_rows(&self, lat: &[f32], rec: &mut [f32]) {
        let dl = self.spec.ae_latent;
        for (row_lat, row_rec) in lat
            .chunks_exact(dl)
            .zip(rec.chunks_exact_mut(self.spec.kv_dim()))
        {
            for (j, o) in row_rec.iter_mut().enumerate() {
                *o = row_lat[j % dl] * 0.5 + row_lat[(j * 7 + 1) % dl] * 0.25;
            }
        }
    }

    /// FNV-1a over a sparse sample of one slot's staged cache rows
    /// (layers × {first, last} row × {first, middle} element).  Folded
    /// into the slot's logits so any staging corruption shifts argmax.
    fn slot_digest(cache: &[f32], slot: usize, l: usize, s: usize, kvd: usize, p: usize) -> u32 {
        let mut h: u32 = 0x811C_9DC5;
        for layer in 0..l {
            for t in [0usize, p.saturating_sub(1)] {
                for j in [0usize, kvd / 2] {
                    let v = cache[slot * l * s * kvd + layer * s * kvd + t * kvd + j];
                    h = (h ^ v.to_bits()).wrapping_mul(0x0100_0193);
                }
            }
        }
        h
    }

    /// Decrement a one-shot fault counter; `Err` exactly when it hits
    /// its armed call.  A non-zero burst re-arms the fault for the next
    /// launch of the same kind after each firing, so retries of the
    /// failed launch keep failing until the burst drains.
    fn tick_fault(counter: &mut Option<u64>, burst: &mut u64, what: &str) -> Result<()> {
        if let Some(n) = *counter {
            if n <= 1 {
                if *burst > 0 {
                    *burst -= 1;
                    *counter = Some(1);
                } else {
                    *counter = None;
                }
                bail!("injected {what} launch fault");
            }
            *counter = Some(n - 1);
        }
        Ok(())
    }

    fn prefill(&mut self, store: &Store, cap: usize) -> Result<Vec<(String, Tensor)>> {
        Self::tick_fault(&mut self.fail_prefill_in, &mut self.burst_prefill, "prefill")?;
        let (l, s, kvd, dl, v) = (
            self.spec.n_layer,
            self.spec.max_seq,
            self.spec.kv_dim(),
            self.spec.ae_latent,
            self.spec.vocab,
        );
        let tokens = store.get("tokens")?.as_i32()?;
        let mask = store.get("len_mask")?.as_f32()?;
        anyhow::ensure!(
            tokens.len() == cap * s && mask.len() == cap * s,
            "prefill inputs must be [{cap}, {s}]"
        );
        let mut bufs: [Vec<f32>; 7] = [
            vec![0.0; cap * v],
            vec![0.0; cap * l * s * kvd],
            vec![0.0; cap * l * s * kvd],
            vec![0.0; cap * l * s * dl],
            vec![0.0; cap * l * s * dl],
            vec![0.0; cap * l * s * kvd],
            vec![0.0; cap * l * s * kvd],
        ];
        for lane in 0..cap {
            // a lane's prompt length is its mask's support; dead lanes
            // (all-zero mask) stay zero, like the compiled graph
            let plen = mask[lane * s..(lane + 1) * s]
                .iter()
                .filter(|&&m| m != 0.0)
                .count();
            if plen == 0 {
                continue;
            }
            let byte = |t: usize| tokens[lane * s + t] as u8;
            for layer in 0..l {
                for t in 0..plen {
                    for j in 0..kvd {
                        let base = lane * l * s * kvd + layer * s * kvd + t * kvd + j;
                        bufs[1][base] = Self::val(1, byte(t), layer, t, j);
                        bufs[2][base] = Self::val(2, byte(t), layer, t, j);
                        bufs[5][base] = Self::val(5, byte(t), layer, t, j);
                        bufs[6][base] = Self::val(6, byte(t), layer, t, j);
                    }
                    for j in 0..dl {
                        let base = lane * l * s * dl + layer * s * dl + t * dl + j;
                        bufs[3][base] = Self::val(3, byte(t), layer, t, j);
                        bufs[4][base] = Self::val(4, byte(t), layer, t, j);
                    }
                }
            }
            for j in 0..v {
                bufs[0][lane * v + j] = Self::val(7, byte(plen - 1), plen, j, j);
            }
        }
        let names = ["logits", "k_raw", "v_raw", "k_lat", "v_lat", "k_eff", "v_eff"];
        let shapes: [Vec<usize>; 7] = [
            vec![cap, v],
            vec![cap, l, s, kvd],
            vec![cap, l, s, kvd],
            vec![cap, l, s, dl],
            vec![cap, l, s, dl],
            vec![cap, l, s, kvd],
            vec![cap, l, s, kvd],
        ];
        Ok(names
            .iter()
            .zip(shapes)
            .zip(bufs)
            .map(|((n, shape), data)| (n.to_string(), Tensor::f32(shape, data)))
            .collect())
    }

    fn decode_step(&mut self, store: &Store, b: usize) -> Result<Vec<(String, Tensor)>> {
        Self::tick_fault(&mut self.fail_decode_in, &mut self.burst_decode, "decode")?;
        let (l, s, kvd, dl, v) = (
            self.spec.n_layer,
            self.spec.max_seq,
            self.spec.kv_dim(),
            self.spec.ae_latent,
            self.spec.vocab,
        );
        let token = store.get("token")?.as_i32()?;
        let pos = store.get("pos")?.as_i32()?;
        let k_cache = store.get("k_cache")?.as_f32()?;
        let v_cache = store.get("v_cache")?.as_f32()?;
        anyhow::ensure!(
            token.len() == b && pos.len() == b && k_cache.len() == b * l * s * kvd,
            "decode_step inputs must be shaped for batch {b}"
        );
        self.drain_region_writes(store, b * l * s * kvd);
        let mut logits = vec![0.0f32; b * v];
        let mut k_lat = vec![0.0f32; b * l * dl];
        let mut v_lat = vec![0.0f32; b * l * dl];
        let mut k_raw = vec![0.0f32; b * l * kvd];
        let mut v_raw = vec![0.0f32; b * l * kvd];
        let mut k_eff = vec![0.0f32; b * l * kvd];
        let mut v_eff = vec![0.0f32; b * l * kvd];
        for slot in 0..b {
            let (tok, p) = (token[slot] as u8, pos[slot] as usize);
            if p == 0 {
                continue; // padding slot
            }
            // the new token's rows: same hash as a prefill of a prompt
            // whose byte at position p is `tok`
            for layer in 0..l {
                for j in 0..kvd {
                    let base = slot * l * kvd + layer * kvd + j;
                    k_raw[base] = Self::val(1, tok, layer, p, j);
                    v_raw[base] = Self::val(2, tok, layer, p, j);
                    k_eff[base] = Self::val(5, tok, layer, p, j);
                    v_eff[base] = Self::val(6, tok, layer, p, j);
                }
                for j in 0..dl {
                    let base = slot * l * dl + layer * dl + j;
                    k_lat[base] = Self::val(3, tok, layer, p, j);
                    v_lat[base] = Self::val(4, tok, layer, p, j);
                }
            }
            // fold the staged cache into the logits so a staging bug
            // anywhere upstream changes the sampled token stream
            let dk = Self::slot_digest(k_cache, slot, l, s, kvd, p);
            let dv = Self::slot_digest(v_cache, slot, l, s, kvd, p);
            let h = dk ^ dv.rotate_left(16);
            for j in 0..v {
                logits[slot * v + j] =
                    Self::val(7, tok, p, j, j) + ((h >> (j % 25)) & 0x7) as f32 * 2e-3;
            }
        }
        Ok(vec![
            ("logits".into(), Tensor::f32(vec![b, v], logits)),
            ("k_lat".into(), Tensor::f32(vec![b, l, dl], k_lat)),
            ("v_lat".into(), Tensor::f32(vec![b, l, dl], v_lat)),
            ("k_raw".into(), Tensor::f32(vec![b, l, kvd], k_raw)),
            ("v_raw".into(), Tensor::f32(vec![b, l, kvd], v_raw)),
            ("k_eff".into(), Tensor::f32(vec![b, l, kvd], k_eff)),
            ("v_eff".into(), Tensor::f32(vec![b, l, kvd], v_eff)),
        ])
    }

    /// Consume the resident k/v regions' dirty-span logs exactly like
    /// the real engine's upload path, and account the delta-vs-full
    /// traffic so residency metrics are meaningful under test.
    fn drain_region_writes(&mut self, store: &Store, region_elems: usize) {
        for name in ["k_cache", "v_cache"] {
            if !store.is_resident_region(name) {
                continue;
            }
            let cur = store.version(name);
            let since = self.last_versions.get(name).copied().unwrap_or(0);
            let full_bytes = (region_elems * 4) as u64;
            if self.device_residency {
                match store.take_region_writes(name, since) {
                    Some(spans) => {
                        let moved: u64 =
                            spans.iter().map(|&(a, b)| ((b - a) * 4) as u64).sum();
                        self.stats.resident_bytes_uploaded += moved;
                        self.stats.resident_bytes_skipped += full_bytes.saturating_sub(moved);
                    }
                    None => {
                        self.stats.resident_bytes_uploaded += full_bytes;
                        self.stats.full_uploads += 1;
                    }
                }
            } else if cur != since {
                self.stats.resident_bytes_uploaded += full_bytes;
                self.stats.full_uploads += 1;
            } else {
                self.stats.resident_bytes_skipped += full_bytes;
            }
            self.last_versions.insert(name.to_string(), cur);
        }
    }

    fn decode_kv(&mut self, store: &Store, shape: &[usize]) -> Result<Vec<(String, Tensor)>> {
        let kvd = self.spec.kv_dim();
        let k_lat = store.get("k_lat")?.as_f32()?;
        let v_lat = store.get("v_lat")?.as_f32()?;
        let elems: usize = shape.iter().product();
        anyhow::ensure!(
            k_lat.len() == elems && v_lat.len() == elems,
            "decode_kv latent inputs must be {shape:?}"
        );
        let rows = elems / self.spec.ae_latent;
        let mut out_shape: Vec<usize> = shape.to_vec();
        *out_shape.last_mut().unwrap() = kvd;
        let mut k_rec = vec![0.0f32; rows * kvd];
        let mut v_rec = vec![0.0f32; rows * kvd];
        self.decode_rows(k_lat, &mut k_rec);
        self.decode_rows(v_lat, &mut v_rec);
        Ok(vec![
            ("k_rec".into(), Tensor::f32(out_shape.clone(), k_rec)),
            ("v_rec".into(), Tensor::f32(out_shape, v_rec)),
        ])
    }
}

impl ExecBackend for MockEngine {
    fn execute(&mut self, entry: &str, store: &Store) -> Result<Vec<(String, Tensor)>> {
        let suffix = entry
            .strip_prefix(&format!("{}_", self.spec.name))
            .ok_or_else(|| anyhow!("mock has no entry '{entry}'"))?
            .to_string();
        let (l, s, dl) = (self.spec.n_layer, self.spec.max_seq, self.spec.ae_latent);
        let out = match suffix.as_str() {
            "prefill" => self.prefill(store, 1),
            "prefill_b" => {
                let cap = self
                    .prefill_capacity
                    .ok_or_else(|| anyhow!("mock has no entry '{entry}'"))?;
                self.prefill(store, cap)
            }
            "decode_kv" => self.decode_kv(store, &[l, s, dl]),
            "decode_kv_t" if self.granular_decode_kv => self.decode_kv(store, &[l, 1, dl]),
            "decode_kv_bt" => {
                let cap = self
                    .kv_bt_capacity
                    .ok_or_else(|| anyhow!("mock has no entry '{entry}'"))?;
                self.decode_kv(store, &[cap, l, 1, dl])
            }
            _ => match suffix
                .strip_prefix("decode_step_b")
                .and_then(|n| n.parse::<usize>().ok())
                .filter(|b| self.decode_batches.contains(b))
            {
                Some(b) => self.decode_step(store, b),
                None => Err(anyhow!("mock has no entry '{entry}'")),
            },
        }?;
        self.stats.executions += 1;
        let out_bytes: u64 = out.iter().map(|(_, t)| t.byte_len() as u64).sum();
        self.stats.output_bytes += out_bytes;
        let e = self.stats.entry_mut(entry);
        e.executions += 1;
        e.output_bytes += out_bytes;
        Ok(out)
    }

    fn load_params(&mut self, _model: &str, _store: &mut Store) -> Result<usize> {
        Ok(0) // the closed-form entries consume no parameters
    }

    fn model_spec(&self, model: &str) -> Result<ModelSpec> {
        anyhow::ensure!(
            model == self.spec.name,
            "mock serves '{}', not '{model}'",
            self.spec.name
        );
        Ok(self.spec.clone())
    }

    fn decode_batches(&self, _model: &str) -> Vec<usize> {
        self.decode_batches.clone()
    }

    fn has_entry(&self, entry: &str) -> bool {
        let Some(suffix) = entry.strip_prefix(&format!("{}_", self.spec.name)) else {
            return false;
        };
        match suffix {
            "prefill" | "decode_kv" => true,
            "prefill_b" => self.prefill_capacity.is_some(),
            "decode_kv_t" => self.granular_decode_kv,
            "decode_kv_bt" => self.kv_bt_capacity.is_some(),
            _ => suffix
                .strip_prefix("decode_step_b")
                .and_then(|n| n.parse::<usize>().ok())
                .is_some_and(|b| self.decode_batches.contains(&b)),
        }
    }

    fn entry_lanes(&self, entry: &str, input: &str) -> Option<usize> {
        if !self.has_entry(entry) {
            return None;
        }
        let suffix = entry.strip_prefix(&format!("{}_", self.spec.name))?;
        match (suffix, input) {
            ("prefill_b", "tokens" | "len_mask" | "last") => self.prefill_capacity,
            ("prefill", "tokens" | "len_mask") => Some(1),
            ("decode_kv_bt", "k_lat" | "v_lat") => self.kv_bt_capacity,
            _ => suffix
                .strip_prefix("decode_step_b")
                .and_then(|n| n.parse::<usize>().ok()),
        }
    }

    fn set_device_residency(&mut self, on: bool) {
        self.device_residency = on;
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn inject_launch_fault(&mut self, kind: &str, nth: u64) -> bool {
        self.inject_launch_fault_burst(kind, nth, 0)
    }

    fn inject_launch_fault_burst(&mut self, kind: &str, nth: u64, burst: u64) -> bool {
        match kind {
            "prefill" => {
                self.fail_prefill_in = Some(nth.max(1));
                self.burst_prefill = burst;
                true
            }
            "decode" => {
                self.fail_decode_in = Some(nth.max(1));
                self.burst_decode = burst;
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::prefill::{LaneWiseMockPrefiller, WavePrefiller};

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "mock".into(),
            arch: crate::model::Arch::Gpt2,
            vocab: 64,
            n_layer: 3,
            d_model: 24,
            n_head: 3,
            n_kv_head: 3,
            d_head: 8,
            ffn_dim: 48,
            max_seq: 32,
            ae_hidden: 16,
            ae_latent: 12,
            bytes_per_el: 4,
        }
    }

    #[test]
    fn prefill_matches_lane_wise_mock_bitwise() {
        let spec = tiny_spec();
        let mut engine = MockEngine::new(spec.clone());
        let mut store = Store::new();
        let prompt: &[u8] = b"hello world";
        let s = spec.max_seq;
        {
            let tokens = store.insert_view_i32_zeroed("tokens", vec![1, s]);
            for (t, &b) in prompt.iter().enumerate() {
                tokens[t] = b as i32;
            }
        }
        {
            let mask = store.insert_view_zeroed("len_mask", vec![1, s]);
            mask[..prompt.len()].fill(1.0);
        }
        store.insert("last", Tensor::scalar_i32(prompt.len() as i32 - 1));
        let out = engine.execute("mock_prefill", &store).unwrap();
        let mut reference = LaneWiseMockPrefiller::for_spec(&spec);
        let wave = reference.prefill_one(prompt, prompt.len()).unwrap();
        for (i, (name, t)) in out.iter().enumerate() {
            let lane = wave.lane(i, 0).unwrap();
            let got = t.as_f32().unwrap();
            assert!(
                got.len() == lane.len()
                    && got.iter().zip(lane).all(|(a, b)| a.to_bits() == b.to_bits()),
                "output {i} ({name}) must match LaneWiseMockPrefiller bitwise"
            );
        }
    }

    #[test]
    fn decode_kv_matches_row_wise_mock_bitwise() {
        let spec = tiny_spec();
        let (l, dl, kvd) = (spec.n_layer, spec.ae_latent, spec.kv_dim());
        let mut engine = MockEngine::new(spec.clone());
        let mut store = Store::new();
        let lat: Vec<f32> = (0..l * dl).map(|i| (i as f32) * 0.03 - 1.0).collect();
        store
            .insert_view("k_lat", vec![l, 1, dl])
            .copy_from_slice(&lat);
        store
            .insert_view("v_lat", vec![l, 1, dl])
            .copy_from_slice(&lat);
        let out = engine.execute("mock_decode_kv_t", &store).unwrap();
        let reference = crate::coordinator::effective::RowWiseMockDecoder::for_spec(&spec);
        let mut k_rec = vec![0.0f32; l * kvd];
        let mut v_rec = vec![0.0f32; l * kvd];
        use crate::coordinator::effective::LatentDecoder;
        let mut r = reference;
        r.decode_latents_into(&lat, &lat, l, &mut k_rec, &mut v_rec)
            .unwrap();
        // decode_latents_into treats n as rows-per-layer; with one row
        // per layer the layouts coincide
        assert_eq!(out[0].1.as_f32().unwrap().len(), l * kvd);
        for (a, b) in out[0].1.as_f32().unwrap().iter().zip(&k_rec) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn launch_faults_fire_once_then_clear() {
        let spec = tiny_spec();
        let mut engine = MockEngine::new(spec.clone());
        assert!(engine.inject_launch_fault("prefill", 2));
        assert!(!engine.inject_launch_fault("compile", 1));
        let mut store = Store::new();
        store.insert_view_i32_zeroed("tokens", vec![1, spec.max_seq]);
        let mask = store.insert_view_zeroed("len_mask", vec![1, spec.max_seq]);
        mask[..4].fill(1.0);
        store.insert("last", Tensor::scalar_i32(3));
        assert!(engine.execute("mock_prefill", &store).is_ok());
        let err = engine.execute("mock_prefill", &store);
        assert!(err.is_err(), "second prefill must hit the armed fault");
        assert!(
            engine.execute("mock_prefill", &store).is_ok(),
            "fault is one-shot"
        );
    }

    #[test]
    fn burst_faults_rearm_for_consecutive_launches() {
        let spec = tiny_spec();
        let mut engine = MockEngine::new(spec.clone());
        assert!(engine.inject_launch_fault_burst("prefill", 1, 2));
        let mut store = Store::new();
        store.insert_view_i32_zeroed("tokens", vec![1, spec.max_seq]);
        let mask = store.insert_view_zeroed("len_mask", vec![1, spec.max_seq]);
        mask[..4].fill(1.0);
        store.insert("last", Tensor::scalar_i32(3));
        for firing in 0..3 {
            assert!(
                engine.execute("mock_prefill", &store).is_err(),
                "firing {firing} of a burst-2 fault must fail"
            );
        }
        assert!(
            engine.execute("mock_prefill", &store).is_ok(),
            "fault clears once the burst drains"
        );
    }
}
