//! Execution-backend abstraction over the AOT artifact runtime.
//!
//! The coordinator's scheduler drives everything through this trait so
//! the same serving loop runs against the real PJRT [`Engine`] or the
//! deterministic [`crate::runtime::MockEngine`] (scenario harness,
//! server tests, CI without artifacts).  The trait is deliberately
//! narrow: entry execution, parameter loading, and the handful of
//! manifest lookups the scheduler performs (model dimensions, compiled
//! decode batch rungs, entry presence and lane capacity).

use super::engine::{Engine, EngineStats};
use super::store::Store;
use super::tensor::Tensor;
use crate::model::ModelSpec;
use crate::util::json::Json;
use anyhow::Result;

/// What the scheduler needs from an execution runtime.
pub trait ExecBackend {
    /// Execute one compiled entry point against the store's staged
    /// inputs, returning its named outputs in the entry's positional
    /// order.
    fn execute(&mut self, entry: &str, store: &Store) -> Result<Vec<(String, Tensor)>>;

    /// Load the model's parameter tensors into `store`; returns the
    /// number of tensors loaded.
    fn load_params(&mut self, model: &str, store: &mut Store) -> Result<usize>;

    /// Runtime model dimensions for `model`.
    fn model_spec(&self, model: &str) -> Result<ModelSpec>;

    /// Compiled decode batch rungs for `model`, smallest first.
    fn decode_batches(&self, model: &str) -> Vec<usize>;

    /// Whether the artifact set has a compiled entry of this name.
    fn has_entry(&self, entry: &str) -> bool;

    /// First-dimension capacity of `input` on `entry` (the compiled
    /// lane/batch capacity of `{m}_prefill_b` / `{m}_decode_kv_bt`);
    /// `None` when the entry or input is absent.
    fn entry_lanes(&self, entry: &str, input: &str) -> Option<usize>;

    /// Toggle device residency for resident store regions (delta
    /// uploads on, full re-uploads off).
    fn set_device_residency(&mut self, on: bool);

    /// Cumulative execution/traffic counters.
    fn stats(&self) -> &EngineStats;

    /// Arm a one-shot launch fault: the `nth` (1-based) subsequent
    /// execution of the given kind (`"prefill"` / `"decode"`) fails
    /// with an injected error, then the fault clears.  Returns whether
    /// the backend supports injection (both the mock and the real
    /// engine do — the engine fails the launch before compiling or
    /// uploading anything).  The scenario harness uses this to prove
    /// the scheduler's transactional guarantees hold mid-wave and
    /// mid-round.
    fn inject_launch_fault(&mut self, kind: &str, nth: u64) -> bool {
        let _ = (kind, nth);
        false
    }

    /// Like [`ExecBackend::inject_launch_fault`], but after firing the
    /// fault re-arms for the next launch of the same kind `burst` more
    /// times — a flapping backend whose retries keep failing, which is
    /// what drives a target past its retry budget into quarantine.
    /// `burst = 0` is exactly the one-shot contract.
    fn inject_launch_fault_burst(&mut self, kind: &str, nth: u64, burst: u64) -> bool {
        let _ = burst;
        self.inject_launch_fault(kind, nth)
    }
}

impl ExecBackend for Engine {
    fn execute(&mut self, entry: &str, store: &Store) -> Result<Vec<(String, Tensor)>> {
        Engine::execute(self, entry, store)
    }

    fn load_params(&mut self, model: &str, store: &mut Store) -> Result<usize> {
        Engine::load_params(self, model, store)
    }

    fn model_spec(&self, model: &str) -> Result<ModelSpec> {
        ModelSpec::from_manifest(&self.manifest.raw, model)
    }

    fn decode_batches(&self, model: &str) -> Vec<usize> {
        self.manifest
            .raw
            .get("models")
            .and_then(|m| m.get(model))
            .and_then(|m| m.get("decode_batches"))
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_else(|| vec![1, 8])
    }

    fn has_entry(&self, entry: &str) -> bool {
        self.manifest.entries.contains_key(entry)
    }

    fn entry_lanes(&self, entry: &str, input: &str) -> Option<usize> {
        self.manifest
            .entries
            .get(entry)
            .and_then(|e| e.inputs.iter().find(|io| io.name == input))
            .and_then(|io| io.shape.first().copied())
    }

    fn set_device_residency(&mut self, on: bool) {
        self.use_device_residency = on;
    }

    fn stats(&self) -> &EngineStats {
        &self.stats
    }

    fn inject_launch_fault(&mut self, kind: &str, nth: u64) -> bool {
        Engine::arm_launch_fault(self, kind, nth, 0)
    }

    fn inject_launch_fault_burst(&mut self, kind: &str, nth: u64, burst: u64) -> bool {
        Engine::arm_launch_fault(self, kind, nth, burst)
    }
}

/// Boxed backends forward transparently, so an owner of
/// `Vec<Box<dyn ExecBackend>>` — the sharded server front end building
/// one backend per router worker — can lend each box out as a
/// `&mut dyn ExecBackend` without unwrapping it.
impl ExecBackend for Box<dyn ExecBackend + '_> {
    fn execute(&mut self, entry: &str, store: &Store) -> Result<Vec<(String, Tensor)>> {
        (**self).execute(entry, store)
    }

    fn load_params(&mut self, model: &str, store: &mut Store) -> Result<usize> {
        (**self).load_params(model, store)
    }

    fn model_spec(&self, model: &str) -> Result<ModelSpec> {
        (**self).model_spec(model)
    }

    fn decode_batches(&self, model: &str) -> Vec<usize> {
        (**self).decode_batches(model)
    }

    fn has_entry(&self, entry: &str) -> bool {
        (**self).has_entry(entry)
    }

    fn entry_lanes(&self, entry: &str, input: &str) -> Option<usize> {
        (**self).entry_lanes(entry, input)
    }

    fn set_device_residency(&mut self, on: bool) {
        (**self).set_device_residency(on)
    }

    fn stats(&self) -> &EngineStats {
        (**self).stats()
    }

    fn inject_launch_fault(&mut self, kind: &str, nth: u64) -> bool {
        (**self).inject_launch_fault(kind, nth)
    }

    fn inject_launch_fault_burst(&mut self, kind: &str, nth: u64, burst: u64) -> bool {
        (**self).inject_launch_fault_burst(kind, nth, burst)
    }
}
