//! Device residency for the buffered execution path: persistent
//! per-input device buffers with chunk-aligned **delta uploads** of
//! store-resident regions (DESIGN.md §7).
//!
//! The decode loop keeps the effective k/v cache in `Store` resident
//! regions and declares the rows it wrote each round
//! ([`crate::runtime::Store::note_region_writes`]).  [`BufferCache`]
//! consumes those spans and re-uploads only the dirty chunks into the
//! existing device buffer — steady-state host→device traffic becomes
//! O(B·L·kvd) per round instead of O(B·L·S·kvd).  Everything degrades
//! to a whole-buffer upload (always correct, never faster) when the
//! backend cannot patch in place, the span log cannot vouch for
//! coverage, or the region's allocation changed.
//!
//! The cache is generic over a [`DeviceBackend`] so planning, chunk
//! alignment, eviction, and byte accounting are unit-testable without a
//! PJRT device ([`MirrorBackend`]); the engine plugs in its PJRT client.

use super::engine::EngineStats;
use super::manifest::IoSpec;
use super::store::Store;
use super::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Default rows per delta-upload chunk (`KVCAR_RESIDENT_CHUNK_ROWS`
/// overrides).  Chunks quantize patch calls: a dirty span re-uploads
/// every chunk it touches, trading a little extra traffic for fewer,
/// larger transfers.
pub const DEFAULT_CHUNK_ROWS: usize = 8;

/// Rows per chunk from the environment (`KVCAR_RESIDENT_CHUNK_ROWS`,
/// default [`DEFAULT_CHUNK_ROWS`]; zero and garbage fall back too).
pub fn chunk_rows_from_env() -> usize {
    std::env::var("KVCAR_RESIDENT_CHUNK_ROWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_CHUNK_ROWS)
}

/// Quantize sorted disjoint element `spans` to `chunk`-element
/// boundaries, clamped to `total`, merging ranges that touch.  The
/// result is sorted, disjoint, and covers every input span.
pub fn chunk_align(spans: &[(usize, usize)], chunk: usize, total: usize) -> Vec<(usize, usize)> {
    let chunk = chunk.max(1);
    let mut out: Vec<(usize, usize)> = Vec::new();
    for &(a, b) in spans {
        let b = b.min(total);
        if a >= b {
            continue;
        }
        let lo = (a / chunk) * chunk;
        let hi = (b.div_ceil(chunk) * chunk).min(total);
        match out.last_mut() {
            Some(last) if lo <= last.1 => last.1 = last.1.max(hi),
            _ => out.push((lo, hi)),
        }
    }
    out
}

/// Host→device transfer surface [`BufferCache`] drives.  `upload` must
/// always work; `patch_f32` may report itself unsupported (`Ok(false)`,
/// without writing), in which case the cache falls back to `upload`.
pub trait DeviceBackend {
    /// Device buffer handle.
    type Buf;

    /// Upload a whole host tensor into a fresh device buffer.
    fn upload(&mut self, t: &Tensor) -> Result<Self::Buf>;

    /// Overwrite `data.len()` f32 elements of `buf` starting at element
    /// offset `at`.  Returns `Ok(false)` — having written nothing —
    /// when the backend cannot patch device memory in place.
    fn patch_f32(&mut self, buf: &mut Self::Buf, at: usize, data: &[f32]) -> Result<bool>;
}

/// One cached device buffer and the host state it mirrors.
struct CachedInput<B> {
    /// store tensor name (eviction checks the region it came from)
    name: String,
    /// store version the device copy is current with
    version: u64,
    /// region epoch at upload time; `Some` iff the tensor was a live
    /// resident region — an epoch change means the backing allocation
    /// was replaced and the device copy is garbage
    epoch: Option<u64>,
    buf: B,
}

/// Per-entry persistent device input buffers with region-aware delta
/// uploads.  Plain tensors get the classic version-keyed treatment
/// (re-upload on change, hit otherwise); resident regions additionally
/// try to consume the store's dirty-span log and patch only the
/// touched chunks.
pub struct BufferCache<B> {
    entries: HashMap<String, Vec<Option<CachedInput<B>>>>,
}

impl<B> Default for BufferCache<B> {
    fn default() -> BufferCache<B> {
        BufferCache::new()
    }
}

impl<B> BufferCache<B> {
    /// Empty cache.
    pub fn new() -> BufferCache<B> {
        BufferCache {
            entries: HashMap::new(),
        }
    }

    /// Make sure `entry` has one buffer slot per input.
    pub fn ensure_entry(&mut self, entry: &str, n_inputs: usize) {
        let slots = self.entries.entry(entry.to_string()).or_default();
        if slots.len() != n_inputs {
            slots.clear();
            slots.resize_with(n_inputs, || None);
        }
    }

    /// Live (cached) device buffers across all entries.
    pub fn live_buffers(&self) -> usize {
        self.entries
            .values()
            .map(|v| v.iter().filter(|s| s.is_some()).count())
            .sum()
    }

    /// Borrow one cached buffer (tests compare device mirrors bitwise).
    pub fn buffer(&self, entry: &str, idx: usize) -> Option<&B> {
        self.entries.get(entry)?.get(idx)?.as_ref().map(|c| &c.buf)
    }

    /// Drop every buffer whose source region was invalidated: the
    /// region's epoch changed (realloc / lapsed re-registration) or the
    /// name is no longer registered at all (release).  Without this
    /// sweep a dead `[b, l, s, kvd]` allocation stays pinned on device
    /// until the entry happens to run again — across a rung switch the
    /// old entry never runs again.  Returns the number dropped.
    pub fn sweep_stale(&mut self, store: &Store) -> u64 {
        let mut dropped = 0;
        for slots in self.entries.values_mut() {
            for s in slots.iter_mut() {
                let stale = matches!(
                    s,
                    Some(c) if c.epoch.is_some_and(|e| {
                        !store.is_resident_region(&c.name) || store.region_epoch(&c.name) != e
                    })
                );
                if stale {
                    *s = None;
                    dropped += 1;
                }
            }
        }
        dropped
    }

    /// Bring input `idx` of `entry` up to date with the store, moving
    /// as few bytes as the span log allows:
    ///
    /// 1. version+epoch unchanged → nothing moves (cache hit);
    /// 2. resident region with a surviving buffer and a consumable span
    ///    log → patch only the chunk-aligned dirty ranges;
    /// 3. otherwise → whole-buffer upload (the always-sound fallback;
    ///    counted in [`EngineStats::full_uploads`] for regions).
    #[allow(clippy::too_many_arguments)]
    pub fn sync_input<D: DeviceBackend<Buf = B>>(
        &mut self,
        dev: &mut D,
        entry: &str,
        idx: usize,
        io: &IoSpec,
        t: &Tensor,
        store: &Store,
        residency: bool,
        chunk_rows: usize,
        stats: &mut EngineStats,
    ) -> Result<()> {
        let slot = self
            .entries
            .get_mut(entry)
            .and_then(|v| v.get_mut(idx))
            .ok_or_else(|| anyhow!("buffer cache: entry '{entry}' input {idx} not sized"))?;
        let ver = store.version(&io.name);
        let bytes = t.byte_len() as u64;
        let region = store.is_resident_region(&io.name);
        let epoch = region.then(|| store.region_epoch(&io.name));
        if let Some(c) = slot.as_ref() {
            if c.version == ver && c.epoch == epoch {
                stats.input_cache_hits += 1;
                if region {
                    stats.resident_bytes_skipped += bytes;
                    stats.entry_mut(entry).resident_bytes_skipped += bytes;
                }
                return Ok(());
            }
        }
        stats.input_uploads += 1;
        if residency && region {
            if let Some(c) = slot.as_mut() {
                if c.epoch == epoch {
                    if let Some(spans) = store.take_region_writes(&io.name, c.version) {
                        let data = t.as_f32()?;
                        let row = io.shape.last().copied().unwrap_or(1).max(1);
                        let chunk = chunk_rows * row;
                        let ranges = chunk_align(&spans, chunk, data.len());
                        let mut patched = true;
                        let mut moved = 0u64;
                        for &(a, b) in &ranges {
                            if dev.patch_f32(&mut c.buf, a, &data[a..b])? {
                                moved += ((b - a) * 4) as u64;
                            } else {
                                // backend can't patch: abandon the delta;
                                // the full upload below replaces the
                                // (possibly part-patched) buffer whole
                                patched = false;
                                break;
                            }
                        }
                        if patched {
                            c.version = ver;
                            stats.input_elements += moved / 4;
                            stats.input_bytes += moved;
                            stats.resident_bytes_uploaded += moved;
                            stats.resident_bytes_skipped += bytes.saturating_sub(moved);
                            let e = stats.entry_mut(entry);
                            e.input_bytes += moved;
                            e.resident_bytes_uploaded += moved;
                            e.resident_bytes_skipped += bytes.saturating_sub(moved);
                            return Ok(());
                        }
                    }
                }
            }
        }
        if region {
            // the whole region is about to be device-current: drain the
            // span log so next round's delta starts from here instead of
            // re-uploading rows this full upload already covered
            let _ = store.take_region_writes(&io.name, u64::MAX);
        }
        let buf = dev.upload(t)?;
        stats.input_elements += t.len() as u64;
        stats.input_bytes += bytes;
        stats.entry_mut(entry).input_bytes += bytes;
        if region {
            stats.full_uploads += 1;
            stats.resident_bytes_uploaded += bytes;
            let e = stats.entry_mut(entry);
            e.full_uploads += 1;
            e.resident_bytes_uploaded += bytes;
        }
        *slot = Some(CachedInput {
            name: io.name.clone(),
            version: ver,
            epoch,
            buf,
        });
        Ok(())
    }

    /// Every input buffer of `entry` in call order (errors if any input
    /// was never synced).
    pub fn buffers(&self, entry: &str) -> Result<Vec<&B>> {
        self.entries
            .get(entry)
            .ok_or_else(|| anyhow!("buffer cache: entry '{entry}' missing"))?
            .iter()
            .enumerate()
            .map(|(i, s)| {
                s.as_ref()
                    .map(|c| &c.buf)
                    .ok_or_else(|| anyhow!("buffer cache: input {i} of '{entry}' not synced"))
            })
            .collect()
    }
}

/// Test/bench backend: "device" buffers are little-endian byte mirrors
/// on the host, with switchable patch support.  `patch_supported =
/// false` models today's PJRT binding (whole-buffer uploads only);
/// `true` measures what a patch-capable device would move.  Mirrors
/// stay bitwise-identical to what a real device would hold, so tests
/// can assert both the cost law and content equality.
#[derive(Debug, Default)]
pub struct MirrorBackend {
    /// honor `patch_f32` (false = full-upload fallback, like PJRT today)
    pub patch_supported: bool,
    /// whole-buffer uploads issued
    pub uploads: u64,
    /// patch calls honored
    pub patches: u64,
    /// bytes moved host→device (uploads + patches)
    pub bytes_moved: u64,
}

impl MirrorBackend {
    /// Backend with in-place patching enabled.
    pub fn patching() -> MirrorBackend {
        MirrorBackend {
            patch_supported: true,
            ..MirrorBackend::default()
        }
    }
}

impl DeviceBackend for MirrorBackend {
    type Buf = Vec<u8>;

    fn upload(&mut self, t: &Tensor) -> Result<Vec<u8>> {
        let bytes = t.to_le_bytes();
        self.uploads += 1;
        self.bytes_moved += bytes.len() as u64;
        Ok(bytes)
    }

    fn patch_f32(&mut self, buf: &mut Vec<u8>, at: usize, data: &[f32]) -> Result<bool> {
        if !self.patch_supported {
            return Ok(false);
        }
        anyhow::ensure!(
            (at + data.len()) * 4 <= buf.len(),
            "patch [{at}, {}) out of range for {}-byte buffer",
            at + data.len(),
            buf.len()
        );
        for (i, v) in data.iter().enumerate() {
            buf[(at + i) * 4..(at + i + 1) * 4].copy_from_slice(&v.to_le_bytes());
        }
        self.patches += 1;
        self.bytes_moved += (data.len() * 4) as u64;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DType;

    fn io(name: &str, shape: Vec<usize>) -> IoSpec {
        IoSpec {
            name: name.to_string(),
            shape,
            dtype: DType::F32,
        }
    }

    fn region_tensor(store: &Store, name: &str) -> Tensor {
        store.get(name).unwrap().clone()
    }

    #[test]
    fn chunk_align_quantizes_and_merges() {
        // spans inside one chunk expand to it; touching chunks merge
        assert_eq!(chunk_align(&[(3, 5)], 4, 16), vec![(0, 8)]);
        assert_eq!(chunk_align(&[(0, 2), (5, 6)], 4, 16), vec![(0, 8)]);
        assert_eq!(chunk_align(&[(0, 2), (9, 10)], 4, 16), vec![(0, 4), (8, 12)]);
        // clamped to the buffer end, empty spans dropped
        assert_eq!(chunk_align(&[(13, 14), (14, 14)], 4, 14), vec![(12, 14)]);
        assert_eq!(chunk_align(&[], 4, 16), Vec::<(usize, usize)>::new());
    }

    #[test]
    fn delta_patches_only_dirty_chunks_and_mirrors_bitwise() {
        let mut store = Store::new();
        let mut cache: BufferCache<Vec<u8>> = BufferCache::new();
        let mut dev = MirrorBackend::patching();
        let mut stats = EngineStats::default();
        let spec = io("r", vec![4, 8]); // 4 rows of 8 elements
        {
            let (d, _) = store.resident_region("r", vec![4, 8]);
            d.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        }
        store.note_region_writes("r", &[(0, 32)]);
        cache.ensure_entry("e", 1);
        let t = region_tensor(&store, "r");
        cache
            .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 1, &mut stats)
            .unwrap();
        // first sight: whole-buffer upload
        assert_eq!(dev.uploads, 1);
        assert_eq!(stats.full_uploads, 1);
        assert_eq!(stats.input_bytes, 32 * 4);
        assert_eq!(cache.buffer("e", 0).unwrap(), &t.to_le_bytes());

        // round 2: touch one row → exactly one row moves
        {
            let (d, _) = store.resident_region("r", vec![4, 8]);
            for v in &mut d[16..24] {
                *v = -1.0;
            }
        }
        store.note_region_writes("r", &[(16, 24)]);
        let t = region_tensor(&store, "r");
        cache
            .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 1, &mut stats)
            .unwrap();
        assert_eq!(dev.uploads, 1, "no second full upload");
        assert_eq!(dev.patches, 1);
        assert_eq!(stats.resident_bytes_uploaded, (32 + 8) * 4);
        assert_eq!(stats.resident_bytes_skipped, 24 * 4);
        assert_eq!(stats.full_uploads, 1);
        assert_eq!(cache.buffer("e", 0).unwrap(), &t.to_le_bytes(), "mirror stays bitwise");

        // round 3: nothing written → declared-clean reopen moves 0 bytes
        store.resident_region("r", vec![4, 8]);
        store.note_region_writes("r", &[]);
        let t = region_tensor(&store, "r");
        cache
            .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 1, &mut stats)
            .unwrap();
        assert_eq!(dev.bytes_moved, (32 + 8) * 4, "clean round is free");
        assert_eq!(cache.buffer("e", 0).unwrap(), &t.to_le_bytes());
    }

    #[test]
    fn chunk_rounding_uploads_whole_chunks() {
        let mut store = Store::new();
        let mut cache: BufferCache<Vec<u8>> = BufferCache::new();
        let mut dev = MirrorBackend::patching();
        let mut stats = EngineStats::default();
        let spec = io("r", vec![8, 4]); // 8 rows of 4 elements
        store.resident_region("r", vec![8, 4]);
        store.note_region_writes("r", &[(0, 32)]);
        cache.ensure_entry("e", 1);
        let t = region_tensor(&store, "r");
        cache
            .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 2, &mut stats)
            .unwrap();
        // one dirty element → its whole 2-row chunk (8 elements) moves
        store.resident_region("r", vec![8, 4]);
        store.note_region_writes("r", &[(13, 14)]);
        let t = region_tensor(&store, "r");
        cache
            .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 2, &mut stats)
            .unwrap();
        assert_eq!(dev.bytes_moved, (32 + 8) * 4);
    }

    #[test]
    fn patch_unsupported_falls_back_to_full_upload() {
        let mut store = Store::new();
        let mut cache: BufferCache<Vec<u8>> = BufferCache::new();
        let mut dev = MirrorBackend::default(); // patch_supported = false
        let mut stats = EngineStats::default();
        let spec = io("r", vec![2, 4]);
        store.resident_region("r", vec![2, 4]);
        store.note_region_writes("r", &[(0, 8)]);
        cache.ensure_entry("e", 1);
        for round in 0..3 {
            {
                let (d, _) = store.resident_region("r", vec![2, 4]);
                d[0] = round as f32;
            }
            store.note_region_writes("r", &[(0, 1)]);
            let t = region_tensor(&store, "r");
            cache
                .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 1, &mut stats)
                .unwrap();
            assert_eq!(cache.buffer("e", 0).unwrap(), &t.to_le_bytes());
        }
        assert_eq!(dev.uploads, 3, "every round re-uploads whole");
        assert_eq!(dev.patches, 0);
        assert_eq!(stats.full_uploads, 3);
        assert_eq!(stats.resident_bytes_uploaded, 3 * 8 * 4);
    }

    #[test]
    fn residency_disabled_always_uploads_whole() {
        let mut store = Store::new();
        let mut cache: BufferCache<Vec<u8>> = BufferCache::new();
        let mut dev = MirrorBackend::patching();
        let mut stats = EngineStats::default();
        let spec = io("r", vec![2, 4]);
        cache.ensure_entry("e", 1);
        for round in 0..2 {
            {
                let (d, _) = store.resident_region("r", vec![2, 4]);
                d[0] = round as f32;
            }
            store.note_region_writes("r", &[(0, 1)]);
            let t = region_tensor(&store, "r");
            cache
                .sync_input(&mut dev, "e", 0, &spec, &t, &store, false, 1, &mut stats)
                .unwrap();
        }
        assert_eq!(dev.uploads, 2, "legacy reference path: no deltas");
        assert_eq!(dev.patches, 0);
    }

    #[test]
    fn undeclared_write_forces_full_upload_not_stale_data() {
        let mut store = Store::new();
        let mut cache: BufferCache<Vec<u8>> = BufferCache::new();
        let mut dev = MirrorBackend::patching();
        let mut stats = EngineStats::default();
        let spec = io("r", vec![2, 4]);
        store.resident_region("r", vec![2, 4]);
        store.note_region_writes("r", &[(0, 8)]);
        cache.ensure_entry("e", 1);
        let t = region_tensor(&store, "r");
        cache
            .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 1, &mut stats)
            .unwrap();
        // open + write WITHOUT declaring: the log refuses to vouch and
        // the engine must move the whole buffer, never serve stale chunks
        {
            let (d, _) = store.resident_region("r", vec![2, 4]);
            d[5] = 99.0;
        }
        let t = region_tensor(&store, "r");
        cache
            .sync_input(&mut dev, "e", 0, &spec, &t, &store, true, 1, &mut stats)
            .unwrap();
        assert_eq!(dev.uploads, 2, "undeclared open → full upload");
        assert_eq!(cache.buffer("e", 0).unwrap(), &t.to_le_bytes());
    }

    #[test]
    fn sweep_drops_buffers_on_epoch_bump_and_release() {
        let mut store = Store::new();
        let mut cache: BufferCache<Vec<u8>> = BufferCache::new();
        let mut dev = MirrorBackend::patching();
        let mut stats = EngineStats::default();
        store.resident_region("k", vec![4]);
        store.note_region_writes("k", &[(0, 4)]);
        store.resident_region("v", vec![4]);
        store.note_region_writes("v", &[(0, 4)]);
        store.insert("w", Tensor::f32(vec![2], vec![1.0, 2.0])); // plain param
        cache.ensure_entry("e", 3);
        for (i, name) in ["k", "v", "w"].iter().enumerate() {
            let t = region_tensor(&store, name);
            let spec = io(name, t.shape().to_vec());
            cache
                .sync_input(&mut dev, "e", i, &spec, &t, &store, true, 1, &mut stats)
                .unwrap();
        }
        assert_eq!(cache.live_buffers(), 3);
        assert_eq!(cache.sweep_stale(&store), 0, "nothing stale yet");

        // realloc k (epoch bump): its buffer is garbage and must go
        store.resident_region("k", vec![8]);
        assert_eq!(cache.sweep_stale(&store), 1);
        assert_eq!(cache.live_buffers(), 2);
        assert!(cache.buffer("e", 0).is_none());

        // release v: the dead region must not stay pinned either
        store.release_region("v");
        assert_eq!(cache.sweep_stale(&store), 1);
        assert_eq!(cache.live_buffers(), 1, "only the plain param survives");
        assert!(cache.buffer("e", 2).is_some(), "plain tensors are never swept");
    }

    #[test]
    fn rung_switch_evicts_the_old_entrys_buffers() {
        // the leak the sweep exists for: a rung switch changes the entry
        // name, so the old entry never executes again — without the
        // sweep its big k/v buffers stay pinned forever
        let mut store = Store::new();
        let mut cache: BufferCache<Vec<u8>> = BufferCache::new();
        let mut dev = MirrorBackend::patching();
        let mut stats = EngineStats::default();
        store.resident_region("k", vec![8, 4]);
        store.note_region_writes("k", &[(0, 32)]);
        cache.ensure_entry("decode_b8", 1);
        let t = region_tensor(&store, "k");
        let spec = io("k", vec![8, 4]);
        cache
            .sync_input(&mut dev, "decode_b8", 0, &spec, &t, &store, true, 1, &mut stats)
            .unwrap();
        assert_eq!(cache.live_buffers(), 1);
        // rung switch: the region reallocs for the new batch capacity
        store.resident_region("k", vec![2, 4]);
        store.note_region_writes("k", &[(0, 8)]);
        let dropped = cache.sweep_stale(&store);
        assert_eq!(dropped, 1, "old rung's buffer evicted without running it");
        cache.ensure_entry("decode_b2", 1);
        let t = region_tensor(&store, "k");
        let spec = io("k", vec![2, 4]);
        cache
            .sync_input(&mut dev, "decode_b2", 0, &spec, &t, &store, true, 1, &mut stats)
            .unwrap();
        assert_eq!(cache.live_buffers(), 1, "exactly the new rung's buffer");
    }
}
