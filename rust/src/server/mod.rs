//! Threaded serving front-end (tokio is not available offline, so the
//! async boundary is a worker thread + channels).
//!
//! The worker owns the PJRT engine and the serving scheduler; clients
//! submit `GenRequest`s from any thread and receive their `GenResponse`
//! over a per-request channel.  Requests arriving while a wave is in
//! flight accumulate and are admitted by the scheduler's continuous
//! batcher on the next wave.
//!
//! Concurrently queued requests dedup automatically: the gather window
//! below batches whatever is in flight into one scheduler run, and
//! under `ServeConfig::prefix_sharing` (default) the admission planner
//! admits every request whose clamped prompt equals an earlier one —
//! in the same wave or any previous wave whose template is still
//! cached — with **zero** prefill launches, sharing the prompt's KV
//! prefix bytes through the cache manager's refcounted trie (DESIGN.md
//! §6).  Template-heavy client traffic (shared system prompts,
//! few-shot headers) therefore pays prefill launches and prefix cache
//! bytes per *distinct* prompt, not per request; each client still
//! gets its own sequence, decode stream, and response.
//!
//! [`Server::start_sharded`] runs the same front end over a
//! [`Router`] of N workers (own engine, KV pool, and tier each):
//! requests place by id-hash affinity with a load-aware override, and
//! the router rebalances live sequences between workers by delta-sync
//! migration (DESIGN.md §10).

use crate::coordinator::{
    GenRequest, GenResponse, Router, RouterConfig, RouterStats, ServeConfig, ServingEngine,
};
use crate::runtime::backend::ExecBackend;
use crate::runtime::Engine;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

enum Msg {
    Generate(GenRequest, Sender<Result<GenResponse, String>>),
    Metrics(Sender<crate::coordinator::metrics::ServeMetrics>),
    RouterStats(Sender<Option<RouterStats>>),
    Shutdown,
}

/// Owns the serving thread; create with `start`, stop with `shutdown`.
pub struct Server {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

/// Cloneable client handle: submit requests from any thread.
pub struct ServerHandle {
    tx: Sender<Msg>,
}

impl Clone for ServerHandle {
    fn clone(&self) -> Self {
        ServerHandle {
            tx: self.tx.clone(),
        }
    }
}

impl ServerHandle {
    /// Blocking generate call (client side).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Generate(req, tx))
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped the request"))?
            .map_err(|e| anyhow!(e))
    }

    /// Snapshot of the engine's serving metrics (worker 0's on a
    /// sharded server — per-worker counters stay per-worker; see
    /// [`ServerHandle::router_stats`] for cluster-level migration and
    /// placement counters).
    pub fn metrics(&self) -> Result<crate::coordinator::metrics::ServeMetrics> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Metrics(tx))
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the request"))
    }

    /// Cluster-level router counters (placements, migrations, delta
    /// bytes); `None` when the server runs a single unsharded worker.
    pub fn router_stats(&self) -> Result<Option<RouterStats>> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::RouterStats(tx))
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped the request"))
    }
}

impl Server {
    /// Start the worker; compiles the model's serving artifacts eagerly.
    pub fn start(artifacts: PathBuf, model: String, cfg: ServeConfig) -> Result<Server> {
        Server::start_with(model, cfg, move || {
            Ok(Box::new(Engine::new(&artifacts)?) as Box<dyn ExecBackend>)
        })
    }

    /// Start the worker over whatever backend `factory` builds **on the
    /// serving thread** (the factory runs there, so the backend never
    /// needs to be `Send` after construction): the deterministic
    /// [`crate::runtime::MockEngine`] in tests, the PJRT artifact
    /// engine in production (`start` is this with an `Engine` factory).
    pub fn start_with<F>(model: String, cfg: ServeConfig, factory: F) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn ExecBackend>> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("kvcar-serve".into())
            .spawn(move || worker(factory, model, cfg, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Server {
            tx,
            handle: Some(handle),
        })
    }

    /// Start a sharded server: `n_workers` router workers, each with
    /// its own engine over the same artifacts, serving behind one
    /// request channel.  Placement, rebalance migration, and drain are
    /// the [`Router`]'s (DESIGN.md §10).
    pub fn start_sharded(
        artifacts: PathBuf,
        model: String,
        cfg: ServeConfig,
        rcfg: RouterConfig,
        n_workers: usize,
    ) -> Result<Server> {
        Server::start_sharded_with(model, cfg, rcfg, n_workers, move || {
            Ok(Box::new(Engine::new(&artifacts)?) as Box<dyn ExecBackend>)
        })
    }

    /// Sharded [`Server::start_with`]: `factory` runs once per worker
    /// **on the serving thread** to build that worker's backend.
    pub fn start_sharded_with<F>(
        model: String,
        cfg: ServeConfig,
        rcfg: RouterConfig,
        n_workers: usize,
        factory: F,
    ) -> Result<Server>
    where
        F: Fn() -> Result<Box<dyn ExecBackend>> + Send + 'static,
    {
        anyhow::ensure!(n_workers >= 1, "a sharded server needs at least one worker");
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("kvcar-serve".into())
            .spawn(move || sharded_worker(factory, model, cfg, rcfg, n_workers, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Server {
            tx,
            handle: Some(handle),
        })
    }

    /// A new client handle.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            tx: self.tx.clone(),
        }
    }

    /// Stop the serving thread and join it.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker<F>(
    factory: F,
    model: String,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
    ready: Sender<Result<(), String>>,
) where
    F: FnOnce() -> Result<Box<dyn ExecBackend>>,
{
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let mut serving = match ServingEngine::new(backend.as_mut(), &model, cfg) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    loop {
        // gather a wave: block for the first request, then drain briefly
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        let mut wave: Vec<(GenRequest, Sender<Result<GenResponse, String>>)> = Vec::new();
        // requests are stamped the moment the worker sees them, so
        // queue_latency/TTFT include the gather window they sat in
        let stamp = |mut req: GenRequest| {
            req.arrival.get_or_insert(serving.clock.now());
            req
        };
        match first {
            Msg::Shutdown => return,
            Msg::Metrics(tx) => {
                let _ = tx.send(serving.metrics.clone());
                continue;
            }
            Msg::RouterStats(tx) => {
                let _ = tx.send(None);
                continue;
            }
            Msg::Generate(req, tx) => wave.push((stamp(req), tx)),
        }
        // A Shutdown observed during the gather window must not be
        // dropped: finish serving the wave already gathered (every
        // accepted request gets its response — the drain guarantee),
        // then exit, which closes the channel so later submits fail
        // fast at the client.
        let mut shutting_down = false;
        let window = Duration::from_millis(2);
        while wave.len() < serving.cfg.max_batch {
            match rx.recv_timeout(window) {
                Ok(Msg::Generate(req, tx)) => wave.push((stamp(req), tx)),
                Ok(Msg::Metrics(tx)) => {
                    let _ = tx.send(serving.metrics.clone());
                }
                Ok(Msg::RouterStats(tx)) => {
                    let _ = tx.send(None);
                }
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(_) => break,
            }
        }
        let reqs: Vec<GenRequest> = wave.iter().map(|(r, _)| r.clone()).collect();
        match serving.run(reqs) {
            Ok(responses) => {
                for (req, tx) in wave {
                    let resp = responses
                        .iter()
                        .find(|r| r.id == req.id)
                        .cloned()
                        .ok_or_else(|| "response missing".to_string());
                    let _ = tx.send(resp);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (_, tx) in wave {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
        if shutting_down {
            return;
        }
    }
}

/// The sharded serving thread: builds one backend per worker through
/// `factory`, wraps them in a [`Router`], and serves gathered waves
/// through it.  Same gather-window and shutdown-drain contract as the
/// single-worker loop.
fn sharded_worker<F>(
    factory: F,
    model: String,
    cfg: ServeConfig,
    rcfg: RouterConfig,
    n_workers: usize,
    rx: Receiver<Msg>,
    ready: Sender<Result<(), String>>,
) where
    F: Fn() -> Result<Box<dyn ExecBackend>>,
{
    let mut backends: Vec<Box<dyn ExecBackend>> = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        match factory() {
            Ok(b) => backends.push(b),
            Err(e) => {
                let _ = ready.send(Err(format!("{e:#}")));
                return;
            }
        }
    }
    let refs: Vec<&mut dyn ExecBackend> = backends.iter_mut().map(|b| b.as_mut()).collect();
    let max_batch = cfg.max_batch;
    let mut router = match Router::new(refs, &model, cfg, rcfg) {
        Ok(r) => r,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    loop {
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        let mut wave: Vec<(GenRequest, Sender<Result<GenResponse, String>>)> = Vec::new();
        let stamp = |router: &Router<'_>, mut req: GenRequest| {
            req.arrival.get_or_insert(router.engine(0).clock.now());
            req
        };
        match first {
            Msg::Shutdown => return,
            Msg::Metrics(tx) => {
                let _ = tx.send(router.engine(0).metrics.clone());
                continue;
            }
            Msg::RouterStats(tx) => {
                let _ = tx.send(Some(router.stats().clone()));
                continue;
            }
            Msg::Generate(req, tx) => {
                let req = stamp(&router, req);
                wave.push((req, tx));
            }
        }
        let mut shutting_down = false;
        let window = Duration::from_millis(2);
        // the cluster admits up to max_batch per worker per wave
        while wave.len() < max_batch * n_workers {
            match rx.recv_timeout(window) {
                Ok(Msg::Generate(req, tx)) => {
                    let req = stamp(&router, req);
                    wave.push((req, tx));
                }
                Ok(Msg::Metrics(tx)) => {
                    let _ = tx.send(router.engine(0).metrics.clone());
                }
                Ok(Msg::RouterStats(tx)) => {
                    let _ = tx.send(Some(router.stats().clone()));
                }
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(_) => break,
            }
        }
        let reqs: Vec<GenRequest> = wave.iter().map(|(r, _)| r.clone()).collect();
        match router.run(reqs) {
            Ok(responses) => {
                for (req, tx) in wave {
                    let resp = responses
                        .iter()
                        .find(|r| r.id == req.id)
                        .cloned()
                        .ok_or_else(|| "response missing".to_string());
                    let _ = tx.send(resp);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (_, tx) in wave {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
        if shutting_down {
            return;
        }
    }
}
