//! Cache block storage formats and row codecs.
//!
//! A block holds `block_size` token rows for one (sequence, layer, K|V)
//! stream.  Rows are encoded per the layer's store kind:
//!
//! * `F32` / `F16`  — raw (or head-subset) KV vectors
//! * `Int8`         — Eq. 4 affine-quantized codes + 8-byte header
//!
//! Latent rows (AE-compressed layers) use the same codecs with
//! `ae_latent` elements — the format is orthogonal to what the elements
//! mean.  f16 conversion is implemented in-tree (no `half` crate offline).

use crate::compress::quant::{dequantize_codes_into, quantize_into, QUANT_HEADER_BYTES};

/// Element encoding for stored rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    /// 4-byte little-endian IEEE 754 single precision (lossless)
    F32,
    /// 2-byte IEEE 754 half precision (the paper's fp16 serving)
    F16,
    /// Eq. 4 affine int8 codes + per-row (scale, zeropoint) header
    Int8,
}

impl Format {
    /// Encoded bytes one `elements`-wide row occupies in this format.
    ///
    /// # Examples
    ///
    /// ```
    /// use kvcar::kvcache::Format;
    /// assert_eq!(Format::F32.row_bytes(64), 256);
    /// assert_eq!(Format::F16.row_bytes(64), 128);
    /// // int8 rows carry an 8-byte (scale, zeropoint) header
    /// assert_eq!(Format::Int8.row_bytes(64), 72);
    /// ```
    pub fn row_bytes(self, elements: usize) -> usize {
        match self {
            Format::F32 => elements * 4,
            Format::F16 => elements * 2,
            // codes + the (scale, zeropoint) header the row codec writes;
            // sharing QUANT_HEADER_BYTES keeps layout and accounting
            // coupled to one definition (regression-tested below)
            Format::Int8 => elements + QUANT_HEADER_BYTES,
        }
    }
}

// --- f16 (IEEE 754 binary16) conversion -----------------------------------

/// Convert f32 to IEEE 754 binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if frac != 0 { 0x0200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal: round-to-nearest-even on the truncated 13 bits
        let mut mant = frac >> 13;
        let rem = frac & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
            mant += 1;
        }
        let mut e16 = (unbiased + 15) as u32;
        if mant == 0x400 {
            mant = 0;
            e16 += 1;
            if e16 >= 0x1F {
                return sign | 0x7C00;
            }
        }
        return sign | ((e16 as u16) << 10) | (mant as u16);
    }
    if unbiased >= -24 {
        // subnormal: mant16 = round(full * 2^(unbiased+1)), full = 1.frac23
        let shift = (-1 - unbiased) as u32;
        let full = frac | 0x80_0000;
        let mant = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mant = if rem > half || (rem == half && (mant & 1) == 1) {
            mant + 1
        } else {
            mant
        };
        return sign | mant as u16;
    }
    sign // underflow -> signed zero
}

/// Convert IEEE 754 binary16 bits to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (frac << 13)
    } else if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: value = frac * 2^-24; normalize to 1.f * 2^(p-24)
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3FF;
            sign | (((114 + e) as u32) << 23) | (f << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

// --- bulk slice codecs -----------------------------------------------------
// Whole-range chunked `to_le_bytes`/`from_le_bytes` conversion instead of
// per-element indexed offset arithmetic; the fixed-width chunk loops
// vectorize.  Int8 stays per-row (each row carries its own affine header).

fn encode_f32_slice(dst: &mut [u8], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len() * 4);
    for (c, &v) in dst.chunks_exact_mut(4).zip(src) {
        c.copy_from_slice(&v.to_le_bytes());
    }
}

fn decode_f32_slice(src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len() * 4);
    for (o, c) in dst.iter_mut().zip(src.chunks_exact(4)) {
        *o = f32::from_le_bytes(c.try_into().unwrap());
    }
}

fn encode_f16_slice(dst: &mut [u8], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len() * 2);
    for (c, &v) in dst.chunks_exact_mut(2).zip(src) {
        c.copy_from_slice(&f32_to_f16_bits(v).to_le_bytes());
    }
}

fn decode_f16_slice(src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len() * 2);
    for (o, c) in dst.iter_mut().zip(src.chunks_exact(2)) {
        *o = f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()));
    }
}

fn encode_int8_row(dst: &mut [u8], src: &[f32]) {
    let (header, codes) = dst.split_at_mut(QUANT_HEADER_BYTES);
    let (scale, zeropoint) = quantize_into(src, codes);
    header[..4].copy_from_slice(&scale.to_le_bytes());
    header[4..8].copy_from_slice(&zeropoint.to_le_bytes());
}

fn decode_int8_row(src: &[u8], dst: &mut [f32]) {
    let (header, codes) = src.split_at(QUANT_HEADER_BYTES);
    let scale = f32::from_le_bytes(header[..4].try_into().unwrap());
    let zeropoint = f32::from_le_bytes(header[4..8].try_into().unwrap());
    dequantize_codes_into(codes, scale, zeropoint, dst);
}

/// Borrowed view over a contiguous row range of one block: readers get
/// the encoded payload (`raw`) or decoded-range access (`decode_into`)
/// without cloning block data.
#[derive(Debug, Clone, Copy)]
pub struct RowsView<'a> {
    /// encoding of the viewed rows
    pub format: Format,
    /// f32 elements per decoded row
    pub elements_per_row: usize,
    /// rows covered by this view
    pub rows: usize,
    data: &'a [u8],
}

impl<'a> RowsView<'a> {
    /// The encoded bytes backing this range (zero-copy).
    pub fn raw(&self) -> &'a [u8] {
        self.data
    }

    /// Decode every row in the view into `out` ([rows * elements] f32).
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.elements_per_row);
        match self.format {
            Format::F32 => decode_f32_slice(self.data, out),
            Format::F16 => decode_f16_slice(self.data, out),
            Format::Int8 => {
                let rb = self.format.row_bytes(self.elements_per_row);
                for (r, o) in self
                    .data
                    .chunks_exact(rb)
                    .zip(out.chunks_exact_mut(self.elements_per_row))
                {
                    decode_int8_row(r, o);
                }
            }
        }
    }
}

/// One storage block: encoded bytes for up to `capacity` rows.
#[derive(Debug, Clone)]
pub struct Block {
    /// element encoding of every row
    pub format: Format,
    /// f32 elements per row
    pub elements_per_row: usize,
    /// row capacity (block_size)
    pub capacity: usize,
    /// rows currently encoded
    pub rows: usize,
    /// encoded bytes, row-major ([capacity, row_bytes])
    pub data: Vec<u8>,
}

impl Block {
    /// Fresh zeroed block for `capacity` rows of `elements_per_row` elements.
    pub fn new(format: Format, elements_per_row: usize, capacity: usize) -> Block {
        Block {
            format,
            elements_per_row,
            capacity,
            rows: 0,
            data: vec![0u8; format.row_bytes(elements_per_row) * capacity],
        }
    }

    /// Whether every row slot is occupied.
    pub fn is_full(&self) -> bool {
        self.rows == self.capacity
    }

    /// Allocated encoded bytes (capacity granularity — the accounting unit).
    pub fn stored_bytes(&self) -> usize {
        self.data.len()
    }

    /// Bulk-encode as many whole rows from `rows` (flat [n, elements]
    /// row-major) as fit in the remaining capacity; returns the number of
    /// rows consumed.  The f32/f16 paths convert the whole range with one
    /// chunked pass (no per-row offset math).
    pub fn push_rows(&mut self, rows: &[f32]) -> usize {
        let epr = self.elements_per_row;
        assert!(epr > 0, "zero-width rows are never stored");
        assert_eq!(rows.len() % epr, 0, "partial row");
        let n = (rows.len() / epr).min(self.capacity - self.rows);
        if n == 0 {
            return 0;
        }
        let rb = self.format.row_bytes(epr);
        let dst = &mut self.data[self.rows * rb..(self.rows + n) * rb];
        let src = &rows[..n * epr];
        match self.format {
            Format::F32 => encode_f32_slice(dst, src),
            Format::F16 => encode_f16_slice(dst, src),
            Format::Int8 => {
                for (d, s) in dst.chunks_exact_mut(rb).zip(src.chunks_exact(epr)) {
                    encode_int8_row(d, s);
                }
            }
        }
        self.rows += n;
        n
    }

    /// Push one already-encoded row range (raw wire bytes, as produced by
    /// `RowsView::raw`) without a decode/encode round-trip — the tier
    /// restore path.  `raw` must be whole rows in this block's format;
    /// consumes as many as fit and returns the row count taken.  Because
    /// the bytes are copied verbatim, a spill/fill cycle through the host
    /// tier is bit-identical for every format (f32, f16, int8 headers).
    pub fn push_raw_rows(&mut self, raw: &[u8]) -> usize {
        let rb = self.format.row_bytes(self.elements_per_row);
        assert_eq!(raw.len() % rb, 0, "partial encoded row");
        let n = (raw.len() / rb).min(self.capacity - self.rows);
        if n == 0 {
            return 0;
        }
        self.data[self.rows * rb..(self.rows + n) * rb].copy_from_slice(&raw[..n * rb]);
        self.rows += n;
        n
    }

    /// Push exactly one row; panics when the block is full.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.elements_per_row);
        assert!(!self.is_full());
        let pushed = self.push_rows(row);
        debug_assert_eq!(pushed, 1);
    }

    /// Borrowed view over rows [start, end) (see `RowsView`).
    pub fn rows_view(&self, start: usize, end: usize) -> RowsView<'_> {
        assert!(start <= end && end <= self.rows, "rows {start}..{end} of {}", self.rows);
        let rb = self.format.row_bytes(self.elements_per_row);
        RowsView {
            format: self.format,
            elements_per_row: self.elements_per_row,
            rows: end - start,
            data: &self.data[start * rb..end * rb],
        }
    }

    /// Decode rows [start, end) into `out` ([(end-start) * elements] f32).
    pub fn decode_rows_into(&self, start: usize, end: usize, out: &mut [f32]) {
        self.rows_view(start, end).decode_into(out);
    }

    /// Decode one row into `out`.
    pub fn read_row(&self, idx: usize, out: &mut [f32]) {
        self.decode_rows_into(idx, idx + 1, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn f16_roundtrip_exact_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "{v}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        check(200, |rng| {
            let v = rng.normal_f32(0.0, 10.0);
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            let rel = ((v - r) / v.abs().max(1e-3)).abs();
            prop_assert!(rel < 1e-3, "v={v} r={r} rel={rel}");
            Ok(())
        });
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00); // overflow
        assert!(f16_bits_to_f32(0x7E00).is_nan());
        assert_eq!(f32_to_f16_bits(1e-10), 0); // underflow to zero
        // subnormal roundtrip
        let sub = f16_bits_to_f32(0x0001);
        assert!(sub > 0.0 && sub < 1e-7);
        assert_eq!(f32_to_f16_bits(sub), 0x0001);
    }

    #[test]
    fn block_f32_roundtrip() {
        let mut b = Block::new(Format::F32, 8, 4);
        let rows: Vec<Vec<f32>> = (0..4).map(|i| (0..8).map(|j| (i * 8 + j) as f32).collect()).collect();
        for r in &rows {
            b.push_row(r);
        }
        assert!(b.is_full());
        let mut out = vec![0.0; 8];
        for (i, r) in rows.iter().enumerate() {
            b.read_row(i, &mut out);
            assert_eq!(&out, r);
        }
    }

    #[test]
    fn block_formats_bounded_error() {
        check(60, |rng| {
            let elements = rng.range(1, 64);
            let fmt = *rng.choice(&[Format::F32, Format::F16, Format::Int8]);
            let mut b = Block::new(fmt, elements, 8);
            let row: Vec<f32> = (0..elements).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            b.push_row(&row);
            let mut out = vec![0.0; elements];
            b.read_row(0, &mut out);
            let tol = match fmt {
                Format::F32 => 0.0,
                Format::F16 => 0.01,
                Format::Int8 => {
                    let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
                    let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    (hi - lo).max(1e-8) / 255.0 + 1e-5
                }
            };
            for (a, c) in row.iter().zip(&out) {
                prop_assert!((a - c).abs() <= tol, "fmt {fmt:?}: {} vs {}", a, c);
            }
            Ok(())
        });
    }

    #[test]
    fn storage_sizes() {
        assert_eq!(Format::F32.row_bytes(64), 256);
        assert_eq!(Format::F16.row_bytes(64), 128);
        assert_eq!(Format::Int8.row_bytes(64), 72);
        let b = Block::new(Format::Int8, 64, 16);
        assert_eq!(b.stored_bytes(), 72 * 16);
    }

    #[test]
    #[should_panic]
    fn overfull_block_panics() {
        let mut b = Block::new(Format::F32, 4, 1);
        b.push_row(&[0.0; 4]);
        b.push_row(&[0.0; 4]);
    }

    #[test]
    fn int8_row_layout_accounts_for_header() {
        // regression: the Int8 codec writes an 8-byte (scale, zeropoint)
        // header per row; row_bytes must include it or a capacity-full
        // block would write out of bounds on its last rows.
        check(30, |rng| {
            let elements = rng.range(1, 96);
            let capacity = rng.range(1, 12);
            prop_assert!(
                Format::Int8.row_bytes(elements) == elements + QUANT_HEADER_BYTES,
                "row_bytes dropped the quant header"
            );
            let mut b = Block::new(Format::Int8, elements, capacity);
            prop_assert!(
                b.data.len() == capacity * (elements + QUANT_HEADER_BYTES),
                "block allocation misses header space"
            );
            let rows: Vec<Vec<f32>> = (0..capacity)
                .map(|_| (0..elements).map(|_| rng.normal_f32(0.0, 2.0)).collect())
                .collect();
            for r in &rows {
                b.push_row(r); // would panic on out-of-bounds writes
            }
            prop_assert!(b.is_full(), "block should be exactly full");
            // every row (incl. the last) reads back within quant error
            let mut out = vec![0.0f32; elements];
            for (i, r) in rows.iter().enumerate() {
                b.read_row(i, &mut out);
                let lo = r.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let tol = (hi - lo).max(1e-8) / 255.0 + 1e-5;
                for (a, c) in r.iter().zip(&out) {
                    prop_assert!((a - c).abs() <= tol, "row {i}: {a} vs {c}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bulk_push_rows_matches_push_row_bitwise() {
        check(40, |rng| {
            let elements = rng.range(1, 48);
            let capacity = rng.range(2, 10);
            let fmt = *rng.choice(&[Format::F32, Format::F16, Format::Int8]);
            let n = rng.range(1, capacity + 1);
            let flat: Vec<f32> = (0..n * elements).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let mut scalar = Block::new(fmt, elements, capacity);
            for r in flat.chunks_exact(elements) {
                scalar.push_row(r);
            }
            let mut bulk = Block::new(fmt, elements, capacity);
            let pushed = bulk.push_rows(&flat);
            prop_assert!(pushed == n, "pushed {pushed} != {n}");
            prop_assert!(bulk.rows == scalar.rows);
            prop_assert!(bulk.data == scalar.data, "encoded bytes diverge ({fmt:?})");
            Ok(())
        });
    }

    #[test]
    fn push_raw_rows_roundtrips_encoded_bytes_bitwise() {
        // the tier spill/fill contract: raw() bytes pushed back through
        // push_raw_rows reproduce the block bit-for-bit in every format
        check(40, |rng| {
            let elements = rng.range(1, 48);
            let fmt = *rng.choice(&[Format::F32, Format::F16, Format::Int8]);
            let capacity = rng.range(2, 10);
            let n = rng.range(1, capacity + 1);
            let flat: Vec<f32> = (0..n * elements).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let mut src = Block::new(fmt, elements, capacity);
            src.push_rows(&flat);
            let wire = src.rows_view(0, n).raw().to_vec();
            let mut dst = Block::new(fmt, elements, capacity);
            let taken = dst.push_raw_rows(&wire);
            prop_assert!(taken == n, "took {taken} of {n} raw rows");
            prop_assert!(dst.rows == src.rows);
            prop_assert!(
                dst.rows_view(0, n).raw() == src.rows_view(0, n).raw(),
                "restored encoded bytes diverge ({fmt:?})"
            );
            Ok(())
        });
    }

    #[test]
    fn push_raw_rows_clamps_to_capacity() {
        let mut b = Block::new(Format::F32, 2, 2);
        let raw = vec![0u8; 3 * Format::F32.row_bytes(2)]; // 3 rows
        assert_eq!(b.push_raw_rows(&raw), 2);
        assert!(b.is_full());
        assert_eq!(b.push_raw_rows(&raw), 0);
    }

    #[test]
    fn push_rows_clamps_to_capacity() {
        let mut b = Block::new(Format::F32, 2, 3);
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect(); // 5 rows
        assert_eq!(b.push_rows(&flat), 3);
        assert!(b.is_full());
        assert_eq!(b.push_rows(&flat), 0);
    }

    #[test]
    fn rows_view_decodes_ranges_without_copy() {
        check(30, |rng| {
            let elements = rng.range(1, 32);
            let fmt = *rng.choice(&[Format::F32, Format::F16, Format::Int8]);
            let n = rng.range(1, 9);
            let mut b = Block::new(fmt, elements, 8);
            let flat: Vec<f32> = (0..n * elements).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            b.push_rows(&flat);
            let start = rng.range(0, n);
            let end = rng.range(start, n + 1);
            let view = b.rows_view(start, end);
            prop_assert!(
                view.raw().len() == (end - start) * fmt.row_bytes(elements),
                "raw view length"
            );
            let mut ranged = vec![0.0f32; (end - start) * elements];
            view.decode_into(&mut ranged);
            // must agree bitwise with per-row reads
            let mut row = vec![0.0f32; elements];
            for (i, chunk) in (start..end).zip(ranged.chunks_exact(elements)) {
                b.read_row(i, &mut row);
                for (a, c) in row.iter().zip(chunk) {
                    prop_assert!(a.to_bits() == c.to_bits(), "range decode diverges");
                }
            }
            Ok(())
        });
    }

    // --- f16 codec properties (subnormals, specials, boundary) -------------

    #[test]
    fn f16_exhaustive_bit_roundtrip() {
        // every finite f16 value is exactly representable in f32, so the
        // f16 -> f32 -> f16 trip must reproduce the exact bit pattern;
        // NaNs must stay NaN (payload may canonicalize)
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1F;
            let frac = h & 0x3FF;
            let x = f16_bits_to_f32(h);
            if exp == 0x1F && frac != 0 {
                assert!(x.is_nan(), "{h:#06x} should decode to NaN");
                assert!(
                    f16_bits_to_f32(f32_to_f16_bits(x)).is_nan(),
                    "{h:#06x} NaN not preserved"
                );
            } else {
                assert_eq!(
                    f32_to_f16_bits(x),
                    h,
                    "{h:#06x} (value {x:e}) does not roundtrip"
                );
            }
        }
    }

    #[test]
    fn f16_subnormal_range_roundtrip_error_bounded() {
        // values in the f16 subnormal range [2^-24, 2^-14): round-to-
        // nearest of a grid with spacing 2^-24 -> error <= 2^-25
        check(200, |rng| {
            let x = (2.0f32.powi(-24) + rng.f32() * (2.0f32.powi(-14) - 2.0f32.powi(-24)))
                * if rng.bool(0.5) { -1.0 } else { 1.0 };
            let r = f16_bits_to_f32(f32_to_f16_bits(x));
            let err = (x - r).abs();
            prop_assert!(
                err <= 2.0f32.powi(-25) * 1.0001,
                "subnormal x={x:e} r={r:e} err={err:e}"
            );
            prop_assert!(
                r == 0.0 || r.signum() == x.signum(),
                "sign flipped: {x:e} -> {r:e}"
            );
            Ok(())
        });
    }

    #[test]
    fn f16_normal_subnormal_boundary_straddle() {
        // values straddling 2^-14 (the smallest f16 normal): both sides
        // round to within half a subnormal ulp (2^-25)
        let boundary = 2.0f32.powi(-14);
        check(200, |rng| {
            let scale = 0.5 + 1.5 * rng.f32(); // [0.5, 2)
            let x = boundary * scale;
            let r = f16_bits_to_f32(f32_to_f16_bits(x));
            prop_assert!(
                (x - r).abs() <= 2.0f32.powi(-25) * 1.0001,
                "boundary x={x:e} r={r:e}"
            );
            Ok(())
        });
        // exactly representable points on both sides are exact
        for exact in [boundary, boundary - 2.0f32.powi(-24), boundary + 2.0f32.powi(-24)] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(exact)), exact, "{exact:e}");
        }
    }

    #[test]
    fn f16_specials_signed() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xFC00), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // signed zeros keep their sign bit
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert!(f16_bits_to_f32(0x8000).is_sign_negative());
        // underflow keeps the sign
        assert_eq!(f32_to_f16_bits(-1e-10), 0x8000);
    }
}
