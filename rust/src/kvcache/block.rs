//! Cache block storage formats and row codecs.
//!
//! A block holds `block_size` token rows for one (sequence, layer, K|V)
//! stream.  Rows are encoded per the layer's store kind:
//!
//! * `F32` / `F16`  — raw (or head-subset) KV vectors
//! * `Int8`         — Eq. 4 affine-quantized codes + 8-byte header
//!
//! Latent rows (AE-compressed layers) use the same codecs with
//! `ae_latent` elements — the format is orthogonal to what the elements
//! mean.  f16 conversion is implemented in-tree (no `half` crate offline).

use crate::compress::quant::{dequantize_into, quantize, QuantVec};

/// Element encoding for stored rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    F32,
    F16,
    Int8,
}

impl Format {
    pub fn row_bytes(self, elements: usize) -> usize {
        match self {
            Format::F32 => elements * 4,
            Format::F16 => elements * 2,
            Format::Int8 => elements + 8, // codes + f32 scale + f32 zeropoint
        }
    }
}

// --- f16 (IEEE 754 binary16) conversion -----------------------------------

pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if frac != 0 { 0x0200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal: round-to-nearest-even on the truncated 13 bits
        let mut mant = frac >> 13;
        let rem = frac & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
            mant += 1;
        }
        let mut e16 = (unbiased + 15) as u32;
        if mant == 0x400 {
            mant = 0;
            e16 += 1;
            if e16 >= 0x1F {
                return sign | 0x7C00;
            }
        }
        return sign | ((e16 as u16) << 10) | (mant as u16);
    }
    if unbiased >= -24 {
        // subnormal: mant16 = round(full * 2^(unbiased+1)), full = 1.frac23
        let shift = (-1 - unbiased) as u32;
        let full = frac | 0x80_0000;
        let mant = full >> shift;
        let rem = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mant = if rem > half || (rem == half && (mant & 1) == 1) {
            mant + 1
        } else {
            mant
        };
        return sign | mant as u16;
    }
    sign // underflow -> signed zero
}

pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (frac << 13)
    } else if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: value = frac * 2^-24; normalize to 1.f * 2^(p-24)
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3FF;
            sign | (((114 + e) as u32) << 23) | (f << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// One storage block: encoded bytes for up to `capacity` rows.
#[derive(Debug, Clone)]
pub struct Block {
    pub format: Format,
    pub elements_per_row: usize,
    pub capacity: usize,
    pub rows: usize,
    pub data: Vec<u8>,
}

impl Block {
    pub fn new(format: Format, elements_per_row: usize, capacity: usize) -> Block {
        Block {
            format,
            elements_per_row,
            capacity,
            rows: 0,
            data: vec![0u8; format.row_bytes(elements_per_row) * capacity],
        }
    }

    pub fn is_full(&self) -> bool {
        self.rows == self.capacity
    }

    pub fn stored_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.elements_per_row);
        assert!(!self.is_full());
        let rb = self.format.row_bytes(self.elements_per_row);
        let off = self.rows * rb;
        match self.format {
            Format::F32 => {
                for (i, &v) in row.iter().enumerate() {
                    self.data[off + i * 4..off + i * 4 + 4].copy_from_slice(&v.to_le_bytes());
                }
            }
            Format::F16 => {
                for (i, &v) in row.iter().enumerate() {
                    self.data[off + i * 2..off + i * 2 + 2]
                        .copy_from_slice(&f32_to_f16_bits(v).to_le_bytes());
                }
            }
            Format::Int8 => {
                let q = quantize(row);
                self.data[off..off + 4].copy_from_slice(&q.scale.to_le_bytes());
                self.data[off + 4..off + 8].copy_from_slice(&q.zeropoint.to_le_bytes());
                for (i, &c) in q.codes.iter().enumerate() {
                    self.data[off + 8 + i] = c as u8;
                }
            }
        }
        self.rows += 1;
    }

    pub fn read_row(&self, idx: usize, out: &mut [f32]) {
        assert!(idx < self.rows);
        assert_eq!(out.len(), self.elements_per_row);
        let rb = self.format.row_bytes(self.elements_per_row);
        let off = idx * rb;
        match self.format {
            Format::F32 => {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = f32::from_le_bytes(
                        self.data[off + i * 4..off + i * 4 + 4].try_into().unwrap(),
                    );
                }
            }
            Format::F16 => {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = f16_bits_to_f32(u16::from_le_bytes(
                        self.data[off + i * 2..off + i * 2 + 2].try_into().unwrap(),
                    ));
                }
            }
            Format::Int8 => {
                let scale = f32::from_le_bytes(self.data[off..off + 4].try_into().unwrap());
                let zeropoint =
                    f32::from_le_bytes(self.data[off + 4..off + 8].try_into().unwrap());
                let codes: Vec<i8> = self.data[off + 8..off + 8 + self.elements_per_row]
                    .iter()
                    .map(|&b| b as i8)
                    .collect();
                dequantize_into(
                    &QuantVec {
                        codes,
                        scale,
                        zeropoint,
                    },
                    out,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn f16_roundtrip_exact_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "{v}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        check(200, |rng| {
            let v = rng.normal_f32(0.0, 10.0);
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            let rel = ((v - r) / v.abs().max(1e-3)).abs();
            prop_assert!(rel < 1e-3, "v={v} r={r} rel={rel}");
            Ok(())
        });
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00); // overflow
        assert!(f16_bits_to_f32(0x7E00).is_nan());
        assert_eq!(f32_to_f16_bits(1e-10), 0); // underflow to zero
        // subnormal roundtrip
        let sub = f16_bits_to_f32(0x0001);
        assert!(sub > 0.0 && sub < 1e-7);
        assert_eq!(f32_to_f16_bits(sub), 0x0001);
    }

    #[test]
    fn block_f32_roundtrip() {
        let mut b = Block::new(Format::F32, 8, 4);
        let rows: Vec<Vec<f32>> = (0..4).map(|i| (0..8).map(|j| (i * 8 + j) as f32).collect()).collect();
        for r in &rows {
            b.push_row(r);
        }
        assert!(b.is_full());
        let mut out = vec![0.0; 8];
        for (i, r) in rows.iter().enumerate() {
            b.read_row(i, &mut out);
            assert_eq!(&out, r);
        }
    }

    #[test]
    fn block_formats_bounded_error() {
        check(60, |rng| {
            let elements = rng.range(1, 64);
            let fmt = *rng.choice(&[Format::F32, Format::F16, Format::Int8]);
            let mut b = Block::new(fmt, elements, 8);
            let row: Vec<f32> = (0..elements).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            b.push_row(&row);
            let mut out = vec![0.0; elements];
            b.read_row(0, &mut out);
            let tol = match fmt {
                Format::F32 => 0.0,
                Format::F16 => 0.01,
                Format::Int8 => {
                    let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
                    let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    (hi - lo).max(1e-8) / 255.0 + 1e-5
                }
            };
            for (a, c) in row.iter().zip(&out) {
                prop_assert!((a - c).abs() <= tol, "fmt {fmt:?}: {} vs {}", a, c);
            }
            Ok(())
        });
    }

    #[test]
    fn storage_sizes() {
        assert_eq!(Format::F32.row_bytes(64), 256);
        assert_eq!(Format::F16.row_bytes(64), 128);
        assert_eq!(Format::Int8.row_bytes(64), 72);
        let b = Block::new(Format::Int8, 64, 16);
        assert_eq!(b.stored_bytes(), 72 * 16);
    }

    #[test]
    #[should_panic]
    fn overfull_block_panics() {
        let mut b = Block::new(Format::F32, 4, 1);
        b.push_row(&[0.0; 4]);
        b.push_row(&[0.0; 4]);
    }
}
