//! Block pool: recycling allocator with byte accounting.
//!
//! Blocks freed when sequences retire are recycled by (format, row
//! elements) class instead of returning to the system allocator — the
//! serving loop allocates and frees cache blocks on every request, and
//! this keeps the hot path free of large allocations.  Accounting feeds
//! the coordinator's admission control and the memory numbers reported in
//! EXPERIMENTS.md (cross-checked against model::memory's Eq. 3 math).

use super::block::{Block, Format};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Class {
    format: Format,
    elements: usize,
    capacity: usize,
}

#[derive(Debug, Default, Clone, Copy, PartialEq)]
/// Byte/operation accounting for a `BlockPool`.
pub struct PoolStats {
    /// bytes in blocks currently handed out
    pub live_bytes: usize,
    /// bytes parked on free lists
    pub free_bytes: usize,
    /// high-water mark of live_bytes
    pub peak_live_bytes: usize,
    /// blocks newly allocated from the system
    pub allocations: u64,
    /// blocks served from a free list
    pub recycles: u64,
    /// blocks returned to the pool
    pub frees: u64,
}

#[derive(Debug, Default)]
/// Recycling block allocator with optional byte budget (see module docs).
pub struct BlockPool {
    free: HashMap<Class, Vec<Block>>,
    stats: PoolStats,
    /// optional cap on live bytes (admission control); None = unlimited
    pub budget_bytes: Option<usize>,
}

impl BlockPool {
    /// Unbounded pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pool that refuses allocations past `budget_bytes` of live blocks.
    pub fn with_budget(budget_bytes: usize) -> Self {
        BlockPool {
            budget_bytes: Some(budget_bytes),
            ..Default::default()
        }
    }

    /// Current accounting snapshot.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Whether one more `capacity`-row block of this class fits the budget.
    pub fn would_fit(&self, format: Format, elements: usize, capacity: usize) -> bool {
        match self.budget_bytes {
            None => true,
            Some(b) => {
                self.stats.live_bytes + format.row_bytes(elements) * capacity <= b
            }
        }
    }

    /// Allocate (or recycle) a block. Returns None if over budget.
    pub fn alloc(&mut self, format: Format, elements: usize, capacity: usize) -> Option<Block> {
        if !self.would_fit(format, elements, capacity) {
            return None;
        }
        let class = Class {
            format,
            elements,
            capacity,
        };
        let block = if let Some(mut b) = self.free.get_mut(&class).and_then(Vec::pop) {
            self.stats.free_bytes -= b.stored_bytes();
            self.stats.recycles += 1;
            b.rows = 0; // reset without zeroing: rows gate all reads
            b
        } else {
            self.stats.allocations += 1;
            Block::new(format, elements, capacity)
        };
        self.stats.live_bytes += block.stored_bytes();
        self.stats.peak_live_bytes = self.stats.peak_live_bytes.max(self.stats.live_bytes);
        Some(block)
    }

    /// Return a block to its class free list (bytes move live -> free).
    pub fn free(&mut self, block: Block) {
        let class = Class {
            format: block.format,
            elements: block.elements_per_row,
            capacity: block.capacity,
        };
        self.stats.live_bytes -= block.stored_bytes();
        self.stats.free_bytes += block.stored_bytes();
        self.stats.frees += 1;
        self.free.entry(class).or_default().push(block);
    }

    /// Drop the free lists (e.g. between experiments).
    pub fn trim(&mut self) {
        self.free.clear();
        self.stats.free_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn alloc_free_recycle() {
        let mut p = BlockPool::new();
        let b = p.alloc(Format::F32, 8, 4).unwrap();
        let bytes = b.stored_bytes();
        assert_eq!(p.stats().live_bytes, bytes);
        p.free(b);
        assert_eq!(p.stats().live_bytes, 0);
        assert_eq!(p.stats().free_bytes, bytes);
        let _b2 = p.alloc(Format::F32, 8, 4).unwrap();
        assert_eq!(p.stats().recycles, 1);
        assert_eq!(p.stats().allocations, 1);
        assert_eq!(p.stats().free_bytes, 0);
    }

    #[test]
    fn recycled_block_is_reset() {
        let mut p = BlockPool::new();
        let mut b = p.alloc(Format::F32, 2, 2).unwrap();
        b.push_row(&[1.0, 2.0]);
        p.free(b);
        let b2 = p.alloc(Format::F32, 2, 2).unwrap();
        assert_eq!(b2.rows, 0);
        assert!(!b2.is_full());
    }

    #[test]
    fn budget_enforced() {
        let mut p = BlockPool::with_budget(100);
        assert!(p.alloc(Format::F32, 8, 4).is_none()); // 128 B > 100
        let b = p.alloc(Format::F32, 4, 4).unwrap(); // 64 B
        assert!(p.alloc(Format::F32, 4, 4).is_none()); // would exceed
        p.free(b);
        assert!(p.alloc(Format::F32, 4, 4).is_some());
    }

    #[test]
    fn accounting_invariants_under_random_traffic() {
        check(40, |rng| {
            let mut p = BlockPool::new();
            let mut live: Vec<Block> = Vec::new();
            let mut expected_live = 0usize;
            for _ in 0..200 {
                if live.is_empty() || rng.bool(0.6) {
                    let elements = rng.range(1, 32);
                    let fmt = *rng.choice(&[Format::F32, Format::F16, Format::Int8]);
                    let b = p.alloc(fmt, elements, 8).unwrap();
                    expected_live += b.stored_bytes();
                    live.push(b);
                } else {
                    let i = rng.below(live.len());
                    let b = live.swap_remove(i);
                    expected_live -= b.stored_bytes();
                    p.free(b);
                }
                prop_assert!(
                    p.stats().live_bytes == expected_live,
                    "live {} != expected {}",
                    p.stats().live_bytes,
                    expected_live
                );
                prop_assert!(p.stats().peak_live_bytes >= p.stats().live_bytes);
            }
            // freeing everything zeroes live bytes
            for b in live.drain(..) {
                p.free(b);
            }
            prop_assert!(p.stats().live_bytes == 0);
            Ok(())
        });
    }

    #[test]
    fn trim_clears_freelists() {
        let mut p = BlockPool::new();
        let b = p.alloc(Format::F16, 8, 8).unwrap();
        p.free(b);
        assert!(p.stats().free_bytes > 0);
        p.trim();
        assert_eq!(p.stats().free_bytes, 0);
        let _ = p.alloc(Format::F16, 8, 8).unwrap();
        assert_eq!(p.stats().allocations, 2); // no recycle after trim
    }
}
