//! Host-offload tier simulation (paper §III "Memory Offloading"
//! complement): sequences evicted from the device cache park their
//! *compressed* blocks in a host tier and pay a modeled PCIe transfer
//! cost on resume.
//!
//! The paper argues KV-CAR composes with offloading because the
//! embedding-dimension compression shrinks the transferred volume; this
//! module quantifies exactly that — `resume_cost` scales with the
//! plan's stored bytes, so an AE+int8 plan moves ~4x less data per
//! evicted sequence than the baseline.

use std::collections::HashMap;
use std::time::Duration;

/// PCIe gen4 x16 effective bandwidth (bytes/sec) used for cost modeling.
pub const PCIE_BYTES_PER_SEC: f64 = 24e9;
/// Fixed per-transfer latency (launch + sync).
pub const TRANSFER_LATENCY_US: f64 = 30.0;

#[derive(Debug, Default)]
pub struct HostTier {
    parked: HashMap<u64, ParkedSeq>,
    pub stats: TierStats,
}

#[derive(Debug, Clone)]
struct ParkedSeq {
    bytes: usize,
    len: usize,
}

#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct TierStats {
    pub evictions: u64,
    pub resumes: u64,
    pub bytes_out: u64,
    pub bytes_in: u64,
    pub host_bytes: usize,
    pub peak_host_bytes: usize,
    /// accumulated modeled transfer time
    pub transfer_time: Duration,
}

pub fn transfer_cost(bytes: usize) -> Duration {
    Duration::from_secs_f64(TRANSFER_LATENCY_US * 1e-6 + bytes as f64 / PCIE_BYTES_PER_SEC)
}

impl HostTier {
    pub fn new() -> Self {
        Self::default()
    }

    /// Park a sequence's compressed payload on the host.
    pub fn evict(&mut self, seq_id: u64, stored_bytes: usize, len: usize) -> Duration {
        let cost = transfer_cost(stored_bytes);
        self.parked.insert(
            seq_id,
            ParkedSeq {
                bytes: stored_bytes,
                len,
            },
        );
        self.stats.evictions += 1;
        self.stats.bytes_out += stored_bytes as u64;
        self.stats.host_bytes += stored_bytes;
        self.stats.peak_host_bytes = self.stats.peak_host_bytes.max(self.stats.host_bytes);
        self.stats.transfer_time += cost;
        cost
    }

    /// Bring a sequence back; returns (cached length, modeled cost).
    pub fn resume(&mut self, seq_id: u64) -> Option<(usize, Duration)> {
        let p = self.parked.remove(&seq_id)?;
        let cost = transfer_cost(p.bytes);
        self.stats.resumes += 1;
        self.stats.bytes_in += p.bytes as u64;
        self.stats.host_bytes -= p.bytes;
        self.stats.transfer_time += cost;
        Some((p.len, cost))
    }

    pub fn is_parked(&self, seq_id: u64) -> bool {
        self.parked.contains_key(&seq_id)
    }

    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt2_774m;
    use crate::model::memory::{kv_bytes_per_token, CompressionPlan};

    #[test]
    fn evict_resume_accounting() {
        let mut tier = HostTier::new();
        let c1 = tier.evict(1, 1_000_000, 64);
        assert!(tier.is_parked(1));
        assert_eq!(tier.stats.host_bytes, 1_000_000);
        let (len, c2) = tier.resume(1).unwrap();
        assert_eq!(len, 64);
        assert!(!tier.is_parked(1));
        assert_eq!(tier.stats.host_bytes, 0);
        assert_eq!(tier.stats.bytes_in, tier.stats.bytes_out);
        assert_eq!(c1, c2);
        assert!(tier.resume(1).is_none());
    }

    #[test]
    fn transfer_cost_scales_with_bytes() {
        let small = transfer_cost(1 << 20);
        let large = transfer_cost(100 << 20);
        assert!(large > small * 10);
        // fixed latency floor
        assert!(transfer_cost(0) >= Duration::from_micros(30));
    }

    #[test]
    fn compression_cuts_offload_volume() {
        // the paper's composition claim, quantified
        let spec = gpt2_774m();
        let tokens = 1024;
        let base = kv_bytes_per_token(&spec, &CompressionPlan::none(spec.n_layer, spec.n_kv_head))
            * tokens;
        let comp = kv_bytes_per_token(
            &spec,
            &CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant(),
        ) * tokens;
        let mut t_base = HostTier::new();
        let mut t_comp = HostTier::new();
        t_base.evict(1, base, tokens);
        t_comp.evict(1, comp, tokens);
        let ratio = t_base.stats.transfer_time.as_secs_f64()
            / t_comp.stats.transfer_time.as_secs_f64();
        assert!(ratio > 3.0, "expected ~4x transfer saving, got {ratio:.2}x");
    }

    #[test]
    fn peak_tracking() {
        let mut tier = HostTier::new();
        tier.evict(1, 100, 1);
        tier.evict(2, 200, 2);
        tier.resume(1);
        tier.evict(3, 50, 1);
        assert_eq!(tier.stats.peak_host_bytes, 300);
        assert_eq!(tier.stats.host_bytes, 250);
        assert_eq!(tier.parked_count(), 2);
    }
}
