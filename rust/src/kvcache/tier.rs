//! Host-offload tier (paper §III "Memory Offloading" complement):
//! sequences evicted from the device cache park their *compressed*
//! payload in a host tier and pay a modeled PCIe transfer cost on
//! resume.
//!
//! Two APIs coexist:
//!
//! * **`park` / `unpark`** — the serving path: the actual encoded block
//!   bytes (`CacheManager::extract_sequence_bytes`, wire format in
//!   DESIGN.md §4) move into the tier and come back bit-identical; the
//!   transfer cost is computed from the payload's real length.
//! * **`evict` / `resume`** — the modeling path (memsim, what-if
//!   analysis): only a byte *count* is tracked, nothing moves.
//!
//! The paper argues KV-CAR composes with offloading because the
//! embedding-dimension compression shrinks the transferred volume; both
//! APIs quantify exactly that — the cost scales with the plan's stored
//! bytes, so an AE+int8 plan moves ~4x less data per evicted sequence
//! than the baseline.

use super::manager::ParkedBytes;
use anyhow::Result;
use std::collections::HashMap;
use std::time::Duration;

/// PCIe gen4 x16 effective bandwidth (bytes/sec) used for cost modeling.
pub const PCIE_BYTES_PER_SEC: f64 = 24e9;
/// Fixed per-transfer latency (launch + sync).
pub const TRANSFER_LATENCY_US: f64 = 30.0;

/// The host-side store for parked sequences plus transfer accounting.
#[derive(Debug, Default)]
pub struct HostTier {
    parked: HashMap<u64, ParkedSeq>,
    /// fault injection: corrupt the payload of this many upcoming parks
    /// *after* their checksum is taken (models an in-flight bit flip;
    /// `unpark_verified` must trip on them)
    corrupt_next: u32,
    /// eviction/resume counters and modeled transfer time
    pub stats: TierStats,
}

#[derive(Debug, Clone)]
struct ParkedSeq {
    bytes: usize,
    len: usize,
    /// CRC32 over the wire payload, taken at park time —
    /// `unpark_verified` re-checks it before the bytes are trusted
    crc: u32,
    /// real encoded payload (`park`); None for modeled `evict` entries
    payload: Option<ParkedBytes>,
}

#[derive(Debug, Default, Clone, Copy, PartialEq)]
/// Transfer accounting for one `HostTier`.
pub struct TierStats {
    /// sequences moved host-ward (park + evict)
    pub evictions: u64,
    /// sequences brought back (unpark + resume)
    pub resumes: u64,
    /// total bytes transferred to the host
    pub bytes_out: u64,
    /// total bytes transferred back to the device
    pub bytes_in: u64,
    /// bytes currently resident in the host tier
    pub host_bytes: usize,
    /// high-water mark of `host_bytes`
    pub peak_host_bytes: usize,
    /// unpark payloads that failed CRC verification (each drops its
    /// entry — corrupted bytes never reach the device cache)
    pub checksum_failures: u64,
    /// accumulated modeled transfer time
    pub transfer_time: Duration,
}

/// CRC32 (IEEE 802.3, reflected polynomial `0xEDB8_8320`) over the
/// headerless tier wire format — the integrity check every real park
/// records and every verified unpark re-derives.  Bitwise (no table):
/// tier payloads are spilled cold paths, not per-round hot paths.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Modeled PCIe transfer time for `bytes` (fixed latency + bandwidth).
pub fn transfer_cost(bytes: usize) -> Duration {
    Duration::from_secs_f64(TRANSFER_LATENCY_US * 1e-6 + bytes as f64 / PCIE_BYTES_PER_SEC)
}

impl HostTier {
    /// Empty tier with zeroed stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// Park a sequence's *actual encoded bytes* on the host — the serving
    /// spill path.  The transfer cost is computed from the payload's real
    /// length (pure rows, no block padding), and `unpark` returns the
    /// identical bytes.  Panics on a double-park: overwriting an entry
    /// would leak the first payload's bytes into `host_bytes` forever.
    pub fn park(&mut self, seq_id: u64, bytes: ParkedBytes) -> Duration {
        assert!(
            !self.parked.contains_key(&seq_id),
            "sequence {seq_id} already parked (double-park corrupts tier accounting)"
        );
        let n = bytes.payload.len();
        let cost = transfer_cost(n);
        self.account_out(n);
        // checksum the sender's bytes *before* any injected corruption:
        // the fault models a bit flip in flight, after the CRC was taken
        let crc = crc32(&bytes.payload);
        let mut entry = ParkedSeq {
            bytes: n,
            len: bytes.len,
            crc,
            payload: Some(bytes),
        };
        if self.corrupt_next > 0 && n > 0 {
            self.corrupt_next -= 1;
            if let Some(p) = entry.payload.as_mut() {
                let at = n / 2;
                p.payload[at] ^= 1 << (at % 8);
            }
        }
        self.parked.insert(seq_id, entry);
        self.stats.transfer_time += cost;
        cost
    }

    /// Arm corruption of the next `n` real parks: a single deterministic
    /// bit flip is applied to each stored payload *after* its CRC is
    /// recorded, so the matching `unpark_verified` must fail.  Fault
    /// injection for the corrupted-transfer scenario legs.
    pub fn inject_corruption(&mut self, n: u32) {
        self.corrupt_next = n;
    }

    /// Undo a just-completed `unpark` whose device-side restore failed:
    /// reinsert the payload and reverse the unpark's accounting, so a
    /// failed resume leaves the stats exactly as if it was never
    /// attempted (no phantom transfers).
    pub fn repark(&mut self, seq_id: u64, bytes: ParkedBytes) {
        assert!(
            !self.parked.contains_key(&seq_id),
            "sequence {seq_id} already parked (repark must follow its unpark)"
        );
        let n = bytes.payload.len();
        self.stats.resumes -= 1;
        self.stats.bytes_in -= n as u64;
        self.stats.host_bytes += n;
        self.stats.peak_host_bytes = self.stats.peak_host_bytes.max(self.stats.host_bytes);
        self.stats.transfer_time -= transfer_cost(n);
        let crc = crc32(&bytes.payload);
        self.parked.insert(
            seq_id,
            ParkedSeq {
                bytes: n,
                len: bytes.len,
                crc,
                payload: Some(bytes),
            },
        );
    }

    /// Bring a parked sequence's encoded bytes back; returns the payload
    /// (ready for `CacheManager::restore_sequence_bytes`) and the modeled
    /// transfer cost.  None when the sequence is not parked here or was
    /// parked through the modeling-only `evict` API.
    pub fn unpark(&mut self, seq_id: u64) -> Option<(ParkedBytes, Duration)> {
        if self.parked.get(&seq_id)?.payload.is_none() {
            return None; // modeled entry: resume() is the matching call
        }
        let p = self.parked.remove(&seq_id)?;
        let cost = transfer_cost(p.bytes);
        self.account_in(p.bytes);
        self.stats.transfer_time += cost;
        Some((p.payload.unwrap(), cost))
    }

    /// `unpark` plus CRC verification — the serving resume path.  On a
    /// checksum mismatch the entry is dropped (the transfer already
    /// happened; corrupted bytes must not be retried or restored),
    /// `stats.checksum_failures` is bumped, and the caller gets a typed
    /// corruption error to quarantine the sequence with.  `Ok(None)`
    /// mirrors `unpark`'s None: not parked here, or a modeled entry.
    pub fn unpark_verified(&mut self, seq_id: u64) -> Result<Option<(ParkedBytes, Duration)>> {
        let want = match self.parked.get(&seq_id) {
            Some(p) if p.payload.is_some() => p.crc,
            _ => return Ok(None),
        };
        let (bytes, cost) = self
            .unpark(seq_id)
            .expect("entry with payload checked above");
        let got = crc32(&bytes.payload);
        if got != want {
            self.stats.checksum_failures += 1;
            anyhow::bail!(
                "checksum mismatch unparking sequence {seq_id}: \
                 payload of {} bytes corrupted in the host tier \
                 (crc {got:#010x} != {want:#010x})",
                bytes.payload.len()
            );
        }
        Ok(Some((bytes, cost)))
    }

    /// Drop a parked entry without transferring it back — quarantine
    /// cleanup for a sequence that died while parked.  Host bytes are
    /// released; no resume or transfer time is charged.  Returns whether
    /// an entry existed.
    pub fn discard(&mut self, seq_id: u64) -> bool {
        match self.parked.remove(&seq_id) {
            Some(p) => {
                self.stats.host_bytes -= p.bytes;
                true
            }
            None => false,
        }
    }

    /// Park a sequence's compressed payload on the host (modeled: only
    /// the byte count is tracked — memsim / what-if analysis).  Panics
    /// on a double-evict, like `park`.
    pub fn evict(&mut self, seq_id: u64, stored_bytes: usize, len: usize) -> Duration {
        assert!(
            !self.parked.contains_key(&seq_id),
            "sequence {seq_id} already parked (double-evict corrupts tier accounting)"
        );
        let cost = transfer_cost(stored_bytes);
        self.account_out(stored_bytes);
        self.parked.insert(
            seq_id,
            ParkedSeq {
                bytes: stored_bytes,
                len,
                crc: 0,
                payload: None,
            },
        );
        self.stats.transfer_time += cost;
        cost
    }

    /// Bring a modeled sequence back; returns (cached length, modeled cost).
    pub fn resume(&mut self, seq_id: u64) -> Option<(usize, Duration)> {
        let p = self.parked.remove(&seq_id)?;
        let cost = transfer_cost(p.bytes);
        self.account_in(p.bytes);
        self.stats.transfer_time += cost;
        Some((p.len, cost))
    }

    fn account_out(&mut self, bytes: usize) {
        self.stats.evictions += 1;
        self.stats.bytes_out += bytes as u64;
        self.stats.host_bytes += bytes;
        self.stats.peak_host_bytes = self.stats.peak_host_bytes.max(self.stats.host_bytes);
    }

    fn account_in(&mut self, bytes: usize) {
        self.stats.resumes += 1;
        self.stats.bytes_in += bytes as u64;
        self.stats.host_bytes -= bytes;
    }

    /// Whether a sequence is currently parked in this tier.
    pub fn is_parked(&self, seq_id: u64) -> bool {
        self.parked.contains_key(&seq_id)
    }

    /// Host bytes a parked sequence occupies (None if not parked).
    pub fn parked_bytes(&self, seq_id: u64) -> Option<usize> {
        self.parked.get(&seq_id).map(|p| p.bytes)
    }

    /// Number of sequences currently parked.
    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt2_774m;
    use crate::model::memory::{kv_bytes_per_token, CompressionPlan};

    #[test]
    fn evict_resume_accounting() {
        let mut tier = HostTier::new();
        let c1 = tier.evict(1, 1_000_000, 64);
        assert!(tier.is_parked(1));
        assert_eq!(tier.stats.host_bytes, 1_000_000);
        let (len, c2) = tier.resume(1).unwrap();
        assert_eq!(len, 64);
        assert!(!tier.is_parked(1));
        assert_eq!(tier.stats.host_bytes, 0);
        assert_eq!(tier.stats.bytes_in, tier.stats.bytes_out);
        assert_eq!(c1, c2);
        assert!(tier.resume(1).is_none());
    }

    #[test]
    fn park_unpark_moves_real_bytes() {
        let mut tier = HostTier::new();
        let bytes = ParkedBytes {
            len: 3,
            prefix_rows: 0,
            demoted: false,
            demoted_spans: Vec::new(),
            payload: vec![7u8, 1, 2, 255, 0, 42],
        };
        let c1 = tier.park(5, bytes.clone());
        assert!(tier.is_parked(5));
        assert_eq!(tier.parked_bytes(5), Some(6));
        assert_eq!(tier.stats.host_bytes, 6);
        // a real park cannot be drained through the modeled resume path
        // by accident — unpark returns the identical payload
        let (back, c2) = tier.unpark(5).unwrap();
        assert_eq!(back, bytes, "payload must round-trip bit-identically");
        assert_eq!(c1, c2);
        assert_eq!(tier.stats.host_bytes, 0);
        assert_eq!(tier.stats.bytes_in, tier.stats.bytes_out);
        assert!(tier.unpark(5).is_none());
        // modeled entries are invisible to unpark
        tier.evict(6, 100, 4);
        assert!(tier.unpark(6).is_none());
        assert!(tier.is_parked(6));
        assert_eq!(tier.resume(6).unwrap().0, 4);
    }

    #[test]
    fn repark_reverses_unpark_accounting() {
        let mut tier = HostTier::new();
        tier.park(
            9,
            ParkedBytes {
                len: 2,
                prefix_rows: 0,
                demoted: false,
                demoted_spans: Vec::new(),
                payload: vec![1, 2, 3, 4],
            },
        );
        let after_park = tier.stats;
        let (bytes, _) = tier.unpark(9).unwrap();
        tier.repark(9, bytes);
        // a failed resume must leave the stats as if never attempted
        assert_eq!(tier.stats, after_park);
        assert!(tier.is_parked(9));
        // and the payload is still intact for the next resume
        let (back, _) = tier.unpark(9).unwrap();
        assert_eq!(back.payload, vec![1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "double-park")]
    fn double_park_panics() {
        let mut tier = HostTier::new();
        let b = ParkedBytes {
            len: 1,
            prefix_rows: 0,
            demoted: false,
            demoted_spans: Vec::new(),
            payload: vec![0],
        };
        tier.park(1, b.clone());
        tier.park(1, b);
    }

    #[test]
    fn transfer_cost_scales_with_bytes() {
        let small = transfer_cost(1 << 20);
        let large = transfer_cost(100 << 20);
        assert!(large > small * 10);
        // fixed latency floor
        assert!(transfer_cost(0) >= Duration::from_micros(30));
    }

    #[test]
    fn compression_cuts_offload_volume() {
        // the paper's composition claim, quantified
        let spec = gpt2_774m();
        let tokens = 1024;
        let base = kv_bytes_per_token(&spec, &CompressionPlan::none(spec.n_layer, spec.n_kv_head))
            * tokens;
        let comp = kv_bytes_per_token(
            &spec,
            &CompressionPlan::ae_first_layers(&spec, spec.n_layer).with_quant(),
        ) * tokens;
        let mut t_base = HostTier::new();
        let mut t_comp = HostTier::new();
        t_base.evict(1, base, tokens);
        t_comp.evict(1, comp, tokens);
        let ratio = t_base.stats.transfer_time.as_secs_f64()
            / t_comp.stats.transfer_time.as_secs_f64();
        assert!(ratio > 3.0, "expected ~4x transfer saving, got {ratio:.2}x");
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // the standard CRC-32/IEEE check vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // a single flipped bit changes the checksum
        assert_ne!(crc32(&[7, 1, 2, 255, 0, 42]), crc32(&[7, 1, 3, 255, 0, 42]));
    }

    #[test]
    fn verified_unpark_round_trips_clean_payloads() {
        let mut tier = HostTier::new();
        let bytes = ParkedBytes {
            len: 3,
            prefix_rows: 1,
            demoted: false,
            demoted_spans: Vec::new(),
            payload: vec![9u8, 8, 7, 6, 5, 4],
        };
        let c1 = tier.park(2, bytes.clone());
        let (back, c2) = tier.unpark_verified(2).unwrap().unwrap();
        assert_eq!(back, bytes);
        assert_eq!(c1, c2);
        assert_eq!(tier.stats.checksum_failures, 0);
        // absent and modeled entries come back as Ok(None), like unpark
        assert!(tier.unpark_verified(2).unwrap().is_none());
        tier.evict(3, 100, 4);
        assert!(tier.unpark_verified(3).unwrap().is_none());
    }

    #[test]
    fn injected_corruption_trips_verification_and_drops_the_entry() {
        let mut tier = HostTier::new();
        tier.inject_corruption(1);
        tier.park(
            4,
            ParkedBytes {
                len: 2,
                prefix_rows: 0,
                demoted: false,
                demoted_spans: Vec::new(),
                payload: vec![1, 2, 3, 4],
            },
        );
        let err = tier.unpark_verified(4).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"));
        assert_eq!(tier.stats.checksum_failures, 1);
        // the entry is gone and its bytes have left the host tier — the
        // transfer happened, it just delivered garbage
        assert!(!tier.is_parked(4));
        assert_eq!(tier.stats.host_bytes, 0);
        assert_eq!(tier.stats.bytes_in, tier.stats.bytes_out);
        // only the armed park was corrupted; the next one is clean
        tier.park(
            5,
            ParkedBytes {
                len: 1,
                prefix_rows: 0,
                demoted: false,
                demoted_spans: Vec::new(),
                payload: vec![42, 43],
            },
        );
        assert!(tier.unpark_verified(5).unwrap().is_some());
        assert_eq!(tier.stats.checksum_failures, 1);
    }

    #[test]
    fn discard_releases_host_bytes_without_a_transfer() {
        let mut tier = HostTier::new();
        tier.park(
            7,
            ParkedBytes {
                len: 2,
                prefix_rows: 0,
                demoted: false,
                demoted_spans: Vec::new(),
                payload: vec![1, 2, 3, 4],
            },
        );
        let before = tier.stats;
        assert!(tier.discard(7));
        assert!(!tier.is_parked(7));
        assert_eq!(tier.stats.host_bytes, 0);
        // no resume / bytes_in / transfer_time charged
        assert_eq!(tier.stats.resumes, before.resumes);
        assert_eq!(tier.stats.bytes_in, before.bytes_in);
        assert_eq!(tier.stats.transfer_time, before.transfer_time);
        assert!(!tier.discard(7));
    }

    #[test]
    fn peak_tracking() {
        let mut tier = HostTier::new();
        tier.evict(1, 100, 1);
        tier.evict(2, 200, 2);
        tier.resume(1);
        tier.evict(3, 50, 1);
        assert_eq!(tier.stats.peak_host_bytes, 300);
        assert_eq!(tier.stats.host_bytes, 250);
        assert_eq!(tier.parked_count(), 2);
    }
}
