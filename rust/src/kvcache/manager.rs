//! The compressed KV-cache manager — the storage half of KV-CAR's
//! contribution, owned by the rust coordinator.
//!
//! Per (layer, K|V) stream the compression plan induces a *store kind*:
//!
//! * `FullAlias`       — every head reused from layer l-1: nothing stored.
//! * `Latent`          — AE layer: `ae_latent` elements per token (the
//!                       encoder output; f32 or int8 per Eq. 4).
//! * `Heads(stored)`   — raw storage for the non-reused head subset.
//!
//! The persistent store holds only compressed payloads; reconstruction to
//! full-width vectors happens on retrieval (decoder artifact + alias
//! resolution), in scratch buffers owned by the scheduler — the paper's
//! decode-on-retrieval dataflow (Fig. 1).  Byte accounting here is the
//! measured counterpart of the Eq. 3 analysis in `model::memory` and the
//! two are cross-checked in tests.

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::allocator::{BlockPool, PoolStats};
use super::block::{Block, Format, RowsView};
use super::prefix::{PrefixIndex, PrefixStats};
use crate::compress::strategy::RegionSpec;
use crate::model::memory::CompressionPlan;
use crate::model::ModelSpec;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, PartialEq, Eq)]
/// What one (layer, K|V) stream persists under the plan.
pub enum StoreKind {
    /// every head reused from layer l-1: nothing stored
    FullAlias,
    /// AE layer: `ae_latent` elements per token
    Latent,
    /// stored (non-reused) head indices, ascending
    Heads(Vec<usize>),
}

impl StoreKind {
    /// Stored f32 elements per token row for this kind.
    pub fn elements(&self, spec: &ModelSpec) -> usize {
        match self {
            StoreKind::FullAlias => 0,
            StoreKind::Latent => spec.ae_latent,
            StoreKind::Heads(h) => h.len() * spec.d_head,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Key or value half of a layer's cache.
pub enum Side {
    /// key stream
    K,
    /// value stream
    V,
}

#[derive(Debug, Clone)]
/// Storage policy: model dims, plan, row formats, block capacity.
pub struct CacheConfig {
    /// model dimensions the rows are sized for
    pub spec: ModelSpec,
    /// which layers compress / which heads alias (induces store kinds)
    pub plan: CompressionPlan,
    /// encoding of raw (non-latent) rows
    pub raw_format: Format,
    /// encoding of latent rows (Int8 when the plan stacks Eq. 4)
    pub latent_format: Format,
    /// token rows per pooled block
    pub block_size: usize,
    /// adaptive per-row-region rung assignments (a validated
    /// [`crate::compress::strategy::PlanManifest`]'s regions, installed
    /// by the serving engine); empty = the uniform legacy policy, where
    /// every row stores under the plan-derived per-stream formats
    pub regions: Vec<RegionSpec>,
}

impl CacheConfig {
    /// Plan-derived defaults: f32 raw rows, int8 latents iff the plan
    /// stacks Eq. 4, 16-row blocks, no adaptive regions.
    pub fn new(spec: ModelSpec, plan: CompressionPlan) -> Self {
        let latent_format = if plan.quant_int8 {
            Format::Int8
        } else {
            Format::F32
        };
        CacheConfig {
            spec,
            plan,
            raw_format: Format::F32,
            latent_format,
            block_size: 16,
            regions: Vec::new(),
        }
    }

    /// The store kind the plan induces for one (layer, side) stream.
    pub fn store_kind(&self, layer: usize, side: Side) -> StoreKind {
        let reuse = match side {
            Side::K => &self.plan.reuse_k[layer],
            Side::V => &self.plan.reuse_v[layer],
        };
        if reuse.iter().all(|&r| r) {
            return StoreKind::FullAlias;
        }
        if self.plan.ae_layers[layer] {
            return StoreKind::Latent;
        }
        StoreKind::Heads(
            (0..self.spec.n_kv_head)
                .filter(|&h| !reuse[h])
                .collect(),
        )
    }

    fn format_for(&self, kind: &StoreKind) -> Format {
        match kind {
            StoreKind::Latent => self.latent_format,
            _ => {
                if self.plan.quant_int8 {
                    Format::Int8
                } else {
                    self.raw_format
                }
            }
        }
    }

    /// The format the adaptive region covering `row` pins byte-bearing
    /// streams to (`None` with no regions installed, or when the
    /// covering region defers to the plan).
    fn region_format(&self, row: usize) -> Option<Format> {
        self.regions
            .iter()
            .find(|r| row >= r.start && r.end.map_or(true, |e| row < e))
            .and_then(|r| r.rung.format_override())
    }

    /// The one format-precedence rule for a stored own row: ladder
    /// demotion (whole-sequence flag or a dynamically demoted span)
    /// beats the static region rung, which beats the plan-derived
    /// default.  Every path that encodes, prices, or re-derives block
    /// formats — appends, restores, delta manifests, the predicted-
    /// bytes law — goes through here, so they can never disagree.
    pub(crate) fn own_row_format(
        &self,
        kind: &StoreKind,
        row: usize,
        demoted: bool,
        demoted_spans: &[(usize, usize)],
    ) -> Format {
        if demoted || demoted_spans.iter().any(|&(a, b)| row >= a && row < b) {
            return Format::Int8;
        }
        if let Some(fmt) = self.region_format(row) {
            return fmt;
        }
        self.format_for(kind)
    }

    /// Per-stream, per-own-block format layout of a sequence's private
    /// suffix store: for every (layer, K|V) stream in wire order
    /// (layer-ascending, K before V), its stored elements per row and
    /// the format of each own block, derived from `(len, prefix_rows,
    /// demoted, demoted_spans)` plus this config alone.  Regions and
    /// demoted spans are block-aligned and `prefix_rows` is
    /// block-aligned, so a block never straddles a format boundary and
    /// its first row's [`CacheConfig::own_row_format`] is the whole
    /// block's format.  With no regions and no spans this degenerates
    /// to [`CacheConfig::wire_layout`] repeated per block — which is
    /// what keeps the adaptive path byte-identical to the legacy one
    /// for uniform manifests.  Both the restore path and the
    /// delta-transfer manifest ([`crate::kvcache::delta`]) read
    /// heterogeneous payloads through this one definition.
    pub(crate) fn own_block_layout(
        &self,
        len: usize,
        prefix_rows: usize,
        demoted: bool,
        demoted_spans: &[(usize, usize)],
    ) -> Vec<(usize, Vec<Format>)> {
        let own = len - prefix_rows;
        let n_blocks = own.div_ceil(self.block_size);
        let mut out = Vec::with_capacity(2 * self.spec.n_layer);
        for layer in 0..self.spec.n_layer {
            for side in [Side::K, Side::V] {
                let kind = self.store_kind(layer, side);
                let epr = kind.elements(&self.spec);
                let fmts = if epr == 0 {
                    Vec::new()
                } else {
                    (0..n_blocks)
                        .map(|b| {
                            let row = prefix_rows + b * self.block_size;
                            self.own_row_format(&kind, row, demoted, demoted_spans)
                        })
                        .collect()
                };
                out.push((epr, fmts));
            }
        }
        out
    }

    /// Exact encoded bytes one token row adds across every stream under
    /// this config's **runtime block formats** — the measured
    /// counterpart of the Eq. 3 `kv_bytes_per_token` model (which prices
    /// every non-int8 stream at `spec.bytes_per_el` and therefore
    /// overstates f16 raw rows 2×).  Block-capacity rounding excluded.
    /// For an all-f32 config the two agree exactly
    /// (`config_bytes_per_token_matches_eq3_for_f32` below), which is
    /// what keeps this accounting and the model cross-checkable.
    pub fn bytes_per_token(&self) -> usize {
        (0..self.spec.n_layer)
            .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
            .map(|(l, s)| {
                let kind = self.store_kind(l, s);
                let epr = kind.elements(&self.spec);
                if epr == 0 {
                    0
                } else {
                    self.format_for(&kind).row_bytes(epr)
                }
            })
            .sum()
    }

    /// Per-stream wire layout of a [`ParkedBytes`] payload: `(format,
    /// elements_per_row)` for every (layer, K|V) stream in wire order
    /// (layer-ascending, K before V).  Fully-aliased streams report
    /// zero elements and contribute no payload bytes.  A `demoted`
    /// payload encodes every byte-bearing stream int8 (the pressure
    /// ladder's rung), exactly as `restore_sequence_bytes` derives —
    /// this is the one definition both the restore path and the
    /// delta-transfer manifest ([`crate::kvcache::delta`]) read the
    /// payload through.
    pub fn wire_layout(&self, demoted: bool) -> Vec<(Format, usize)> {
        let mut layout = Vec::with_capacity(2 * self.spec.n_layer);
        for layer in 0..self.spec.n_layer {
            for side in [Side::K, Side::V] {
                let kind = self.store_kind(layer, side);
                let epr = kind.elements(&self.spec);
                let fmt = if demoted && epr > 0 {
                    Format::Int8
                } else {
                    self.format_for(&kind)
                };
                layout.push((fmt, epr));
            }
        }
        layout
    }
}

/// Rows of one stream read back from the store, decoded to f32 into
/// owned buffers.  The zero-copy counterpart is `CacheManager::stream`.
#[derive(Debug, Clone)]
pub enum StoredRows {
    /// nothing stored — resolve from layer l-1
    Alias,
    /// [len, ae_latent] row-major latents (run the decoder artifact)
    Latent(Vec<f32>),
    /// [len, stored_heads * d_head] row-major raw slices + head indices
    Heads(Vec<f32>, Vec<usize>),
}

/// Borrowed view of one stream's rows — the incremental retrieval API.
/// Callers decode only the row ranges they need (typically "rows since
/// the `decoded_upto` watermark") straight into their own buffers.
pub enum StreamRows<'a> {
    /// nothing stored — resolve from layer l-1
    Alias,
    /// latent rows (run the decoder artifact over the decoded range)
    Latent(StreamView<'a>),
    /// raw head-subset rows + stored (non-reused) head indices
    Heads(StreamView<'a>, &'a [usize]),
}

/// Block list behind a [`StreamView`].  `stream()` sits on the
/// per-round decode path, so neither case allocates: the common
/// (unshared) case borrows the sequence's contiguous private block run,
/// and a prefix-shared sequence resolves chain blocks through the trie
/// on demand (an O(1) arena index per access) before falling through to
/// its private suffix blocks.
enum ViewBlocks<'a> {
    /// the sequence's own blocks, borrowed as-is (no shared prefix)
    Contiguous(&'a [Block]),
    /// shared prefix chain followed by private suffix blocks
    Chained {
        /// trie holding the chain's blocks
        index: &'a PrefixIndex,
        /// the sequence's chain, root→leaf (block `i < path.len()`)
        path: &'a [u32],
        /// stream coordinates inside each chain node
        layer: usize,
        /// K or V half of the stream
        side: Side,
        /// private suffix blocks (block `i - path.len()`)
        own: &'a [Block],
    },
}

impl<'a> ViewBlocks<'a> {
    fn get(&self, i: usize) -> &'a Block {
        match self {
            ViewBlocks::Contiguous(s) => &s[i],
            ViewBlocks::Chained {
                index,
                path,
                layer,
                side,
                own,
            } => {
                if i < path.len() {
                    index
                        .block(path[i], *layer, *side)
                        .expect("stored stream must have a block in every prefix chunk")
                } else {
                    &own[i - path.len()]
                }
            }
        }
    }
}

/// Block-spanning, borrowed row-range access for one (seq, layer, K|V)
/// stream: no owned copies of block data, decode on demand.
///
/// The block list chains the sequence's shared-prefix blocks (if it was
/// admitted against a [`PrefixIndex`] chain — all full, block-aligned)
/// before its own suffix blocks, so readers never see the ownership
/// split: row indexing, range decodes, and raw views are identical for
/// shared and private sequences.
pub struct StreamView<'a> {
    blocks: ViewBlocks<'a>,
    len: usize,
    elements_per_row: usize,
}

impl<'a> StreamView<'a> {
    /// Token rows readable through this view.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the stream holds no rows yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Decoded f32 elements per row.
    pub fn elements_per_row(&self) -> usize {
        self.elements_per_row
    }

    /// Decode rows [start, end) into `out` ([(end-start) * elements]
    /// f32), walking blocks without copying encoded bytes.
    pub fn decode_range_into(&self, start: usize, end: usize, out: &mut [f32]) {
        assert!(
            start <= end && end <= self.len,
            "row range {start}..{end} outside 0..{}",
            self.len
        );
        let epr = self.elements_per_row;
        assert_eq!(out.len(), (end - start) * epr);
        if start == end {
            return;
        }
        let cap = self.blocks.get(0).capacity;
        let (mut row, mut off) = (start, 0usize);
        while row < end {
            let (b, i) = (row / cap, row % cap);
            let take = (cap - i).min(end - row);
            self.blocks
                .get(b)
                .decode_rows_into(i, i + take, &mut out[off..off + take * epr]);
            row += take;
            off += take * epr;
        }
    }

    /// Decode the whole stream into a fresh buffer.
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len * self.elements_per_row];
        self.decode_range_into(0, self.len, &mut out);
        out
    }

    /// Encoded bytes of rows [start, end) as per-block borrowed views
    /// (zero-copy; e.g. tier transfer without a decode round-trip).
    pub fn raw_views(&self, start: usize, end: usize) -> Vec<RowsView<'a>> {
        assert!(start <= end && end <= self.len);
        let mut views = Vec::new();
        if start == end {
            return views;
        }
        let cap = self.blocks.get(0).capacity;
        let mut row = start;
        while row < end {
            let (b, i) = (row / cap, row % cap);
            let take = (cap - i).min(end - row);
            views.push(self.blocks.get(b).rows_view(i, i + take));
            row += take;
        }
        views
    }
}

/// A sequence's compressed payload extracted for a tier transfer: the
/// *actual encoded block bytes*, not a modeled byte count.
///
/// Wire format (documented in `rust/DESIGN.md` §4): streams concatenated
/// layer-ascending, K before V; each stored stream contributes its own
/// blocks' filled rows back-to-back, each block's rows encoded under
/// that block's format — `rows * format.row_bytes(elements_per_row)`
/// bytes per block (block padding is stripped — partial trailing blocks
/// contribute only their filled rows).  Fully-aliased streams
/// contribute nothing.  Formats and row widths are derived on restore
/// from the compression plan, the adaptive regions, and this struct's
/// own `demoted`/`demoted_spans` flags
/// ([`CacheConfig::own_block_layout`]), so the payload needs no
/// per-stream or per-block header and round-trips bit-identically for
/// f32, f16, and int8 (Eq. 4 headers included), uniform or mixed-rung.
///
/// `prefix_rows` is the park/resume side of cross-request prefix
/// sharing (DESIGN.md §6): a sequence admitted against a shared prefix
/// chain spills only its **own suffix rows** — the shared prefix stays
/// device-resident and refcounted for its other sharers, so parking a
/// sharer moves fewer bytes and can never strand or double-free prefix
/// blocks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParkedBytes {
    /// token rows the sequence covers in total (prefix + suffix)
    pub len: usize,
    /// leading rows resident in the shared prefix store (not in the
    /// payload; 0 for unshared sequences)
    pub prefix_rows: usize,
    /// the sequence was demoted to the int8 rung before parking: every
    /// stored stream in the payload is int8-encoded regardless of the
    /// plan's formats, and restore must derive the layout accordingly
    pub demoted: bool,
    /// block-aligned own-row spans the pressure ladder demoted
    /// *regionally* (sorted, disjoint, absolute row indices): rows in
    /// these spans are int8-encoded in the payload whatever the plan or
    /// region rung says, and restore derives the per-block layout
    /// accordingly.  Empty for sequences the ladder never touched.
    pub demoted_spans: Vec<(usize, usize)>,
    /// concatenated encoded suffix stream bytes (see wire format above)
    pub payload: Vec<u8>,
}

struct Stream {
    kind: StoreKind,
    blocks: Vec<Block>,
}

struct SeqCache {
    len: usize,
    /// decode watermark: rows [0, decoded_upto) are already materialized
    /// in some effective-cache scratch; retrieval asks for "rows since"
    decoded_upto: usize,
    /// compressed payload currently lives in the host tier — the blocks
    /// were freed back to the device pool and reads must fail until
    /// `restore_sequence_bytes` brings the bytes back.  A parked sharer
    /// keeps its `prefix_path` references: only suffix bytes move.
    parked: bool,
    /// shared prefix chain (root→leaf `PrefixIndex` nodes) this sequence
    /// references; empty for unshared sequences.  The chain's blocks
    /// cover rows [0, prefix_rows) of every stored stream; the
    /// sequence's own `streams` blocks cover [prefix_rows, len).
    prefix_path: Vec<u32>,
    /// rows covered by the shared chain (block-aligned; 0 = unshared)
    prefix_rows: usize,
    /// the pressure ladder demoted this sequence's own blocks to the
    /// int8 rung: existing rows were re-encoded, future appends and
    /// park/restore layouts use int8 for every stored stream
    demoted: bool,
    /// block-aligned own-row spans demoted regionally by the adaptive
    /// ladder (sorted, disjoint, absolute rows): their blocks were
    /// re-encoded int8 and appends landing inside them encode int8,
    /// whatever the plan or region rung says.  Carried through
    /// [`ParkedBytes`] so park/unpark and migration re-derive the same
    /// per-block layout.
    demoted_spans: Vec<(usize, usize)>,
    /// [layer][side] streams, side 0 = K, 1 = V — suffix rows only
    streams: Vec<[Stream; 2]>,
}

/// Merge `[start, end)` into a sorted, disjoint span list, coalescing
/// overlapping or adjacent spans.
fn merge_span(spans: &mut Vec<(usize, usize)>, start: usize, end: usize) {
    spans.push((start, end));
    spans.sort_unstable();
    let mut merged: Vec<(usize, usize)> = Vec::with_capacity(spans.len());
    for &(a, b) in spans.iter() {
        match merged.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => merged.push((a, b)),
        }
    }
    *spans = merged;
}

/// Per-sequence compressed block store: create/append/stream/park
/// sequences under one `CacheConfig` and one recycling block pool, plus
/// the cross-request shared-prefix trie ([`PrefixIndex`], DESIGN.md §6)
/// whose refcounted chunk blocks sharers read through the same
/// [`StreamView`] API as private rows.
///
/// # Examples
///
/// Append one token's storage rows and stream them back zero-copy:
///
/// ```
/// use kvcar::kvcache::{CacheConfig, CacheManager, Side, StreamRows};
/// use kvcar::model::gpt2_774m;
/// use kvcar::model::memory::CompressionPlan;
///
/// let spec = gpt2_774m();
/// let plan = CompressionPlan::ae_first_layers(&spec, 4);
/// let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
/// let id = m.create_sequence();
/// let lat = vec![0.25f32; spec.n_layer * spec.ae_latent];
/// let raw = vec![0.5f32; spec.n_layer * spec.kv_dim()];
/// m.append_token(id, &lat, &lat, &raw, &raw)?;
/// assert_eq!(m.seq_len(id), Some(1));
/// // layer 0 is AE-compressed under this plan: the stream holds latents
/// match m.stream(id, 0, Side::K)? {
///     StreamRows::Latent(view) => assert_eq!(view.len(), 1),
///     _ => panic!("expected a latent stream"),
/// }
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct CacheManager {
    /// storage policy this manager encodes rows under
    pub cfg: CacheConfig,
    pool: BlockPool,
    seqs: HashMap<u64, SeqCache>,
    prefix: PrefixIndex,
    next_id: u64,
}

impl CacheManager {
    /// Manager with an unbounded block pool.
    pub fn new(cfg: CacheConfig) -> Self {
        cfg.plan.validate().expect("invalid compression plan");
        CacheManager {
            cfg,
            pool: BlockPool::new(),
            seqs: HashMap::new(),
            prefix: PrefixIndex::new(),
            next_id: 1,
        }
    }

    /// Manager whose pool refuses allocations past `budget_bytes`.
    pub fn with_budget(cfg: CacheConfig, budget_bytes: usize) -> Self {
        let mut m = Self::new(cfg);
        m.pool = BlockPool::with_budget(budget_bytes);
        m
    }

    /// Block-pool accounting snapshot.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Sequences currently tracked (parked ones included).
    pub fn n_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Ids of every tracked sequence, sorted.  Inspection hook for the
    /// scenario harness's leak checks: after any (possibly failed)
    /// scheduler round, this set must equal the scheduler's own active
    /// set — a sequence here with no owner is a leak, one missing is a
    /// dangling handle.
    pub fn sequence_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.seqs.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Register an empty sequence; returns its id.
    pub fn create_sequence(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let spec = &self.cfg.spec;
        let streams = (0..spec.n_layer)
            .map(|l| {
                [
                    Stream {
                        kind: self.cfg.store_kind(l, Side::K),
                        blocks: Vec::new(),
                    },
                    Stream {
                        kind: self.cfg.store_kind(l, Side::V),
                        blocks: Vec::new(),
                    },
                ]
            })
            .collect();
        self.seqs.insert(
            id,
            SeqCache {
                len: 0,
                decoded_upto: 0,
                parked: false,
                prefix_path: Vec::new(),
                prefix_rows: 0,
                demoted: false,
                demoted_spans: Vec::new(),
                streams,
            },
        );
        id
    }

    /// Drop a sequence: recycle its own suffix blocks and release its
    /// shared-prefix references (chunks nothing references any more are
    /// recycled too — a sharer's retirement can never strand prefix
    /// bytes, and a double-free would trip the refcount assertions).
    /// Safe on parked sequences: they hold no suffix blocks, only the
    /// prefix references this releases.
    pub fn free_sequence(&mut self, id: u64) {
        if let Some(seq) = self.seqs.remove(&id) {
            for mut pair in seq.streams {
                for s in pair.iter_mut() {
                    for b in s.blocks.drain(..) {
                        self.pool.free(b);
                    }
                }
            }
            if let Some(&leaf) = seq.prefix_path.last() {
                self.prefix.detach(leaf, &mut self.pool);
            }
        }
    }

    /// Token rows appended to a sequence (None if unknown).
    pub fn seq_len(&self, id: u64) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// Append one token's storage rows for every layer.
    ///
    /// `k_lat`/`v_lat`: [L * ae_latent] row-major latents (decode_step /
    /// encode_kv outputs — ignored for non-AE layers);
    /// `k_raw`/`v_raw`: [L * kv_dim] raw rows (ignored for AE layers).
    pub fn append_token(
        &mut self,
        id: u64,
        k_lat: &[f32],
        v_lat: &[f32],
        k_raw: &[f32],
        v_raw: &[f32],
    ) -> Result<()> {
        self.append_rows(id, 1, 1, k_lat, v_lat, k_raw, v_raw)
    }

    /// Bulk-append `n` tokens' storage rows for every layer from
    /// prefill-shaped buffers (the streaming ingest path: rows cross
    /// block boundaries through `Block::push_rows`, no per-token calls).
    ///
    /// `k_lat`/`v_lat`: [L, stride, ae_latent] row-major latents;
    /// `k_raw`/`v_raw`: [L, stride, kv_dim] raw rows; token t of layer l
    /// sits at `l * stride * width + t * width` and `n <= stride`.
    pub fn append_rows(
        &mut self,
        id: u64,
        n: usize,
        stride: usize,
        k_lat: &[f32],
        v_lat: &[f32],
        k_raw: &[f32],
        v_raw: &[f32],
    ) -> Result<()> {
        self.append_range(id, 0, n, stride, k_lat, v_lat, k_raw, v_raw)
    }

    /// Append buffer rows `[from, to)` — the range-offset core of
    /// `append_rows`, also used by the shared-prefix ingest to append
    /// only the unshared suffix of a prefill lane's buffers (token `t`
    /// of layer `l` sits at `l * stride * width + t * width`).
    #[allow(clippy::too_many_arguments)]
    fn append_range(
        &mut self,
        id: u64,
        from: usize,
        to: usize,
        stride: usize,
        k_lat: &[f32],
        v_lat: &[f32],
        k_raw: &[f32],
        v_raw: &[f32],
    ) -> Result<()> {
        if from >= to {
            return Ok(());
        }
        let n = to - from;
        let spec = self.cfg.spec.clone();
        let (l, dl, kvd, dh) = (spec.n_layer, spec.ae_latent, spec.kv_dim(), spec.d_head);
        anyhow::ensure!(to <= stride, "row range exceeds buffer stride");
        anyhow::ensure!(
            k_lat.len() == l * stride * dl && v_lat.len() == l * stride * dl,
            "latent shape"
        );
        anyhow::ensure!(
            k_raw.len() == l * stride * kvd && v_raw.len() == l * stride * kvd,
            "raw shape"
        );
        let seq = self
            .seqs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        anyhow::ensure!(!seq.parked, "sequence {id} is parked in the host tier");
        anyhow::ensure!(seq.len + n <= spec.max_seq, "sequence at max_seq");

        let mut gather: Vec<f32> = Vec::new();
        for layer in 0..l {
            for (side, lat, raw) in [(0usize, k_lat, k_raw), (1, v_lat, v_raw)] {
                // borrow dance: assemble the rows before touching the stream
                let kind = seq.streams[layer][side].kind.clone();
                let rows = gather_stream_rows(
                    &kind,
                    layer,
                    from,
                    to,
                    stride,
                    (dl, kvd, dh),
                    lat,
                    raw,
                    &mut gather,
                );
                if let Some(mut rows) = rows {
                    let epr = kind.elements(&spec);
                    // copy the format inputs out before mutably
                    // borrowing the stream (field-disjoint borrows)
                    let demoted = seq.demoted;
                    let prefix_rows = seq.prefix_rows;
                    let spans = seq.demoted_spans.clone();
                    let stream = &mut seq.streams[layer][side];
                    while !rows.is_empty() {
                        if stream.blocks.last().map_or(true, Block::is_full) {
                            // each freshly-allocated block takes the
                            // format its first row's rung pins — the
                            // one precedence rule in `own_row_format`
                            // (ladder demotion > region rung > plan)
                            let row0 = prefix_rows + stream.blocks.len() * self.cfg.block_size;
                            let fmt = self.cfg.own_row_format(&kind, row0, demoted, &spans);
                            let b = self
                                .pool
                                .alloc(fmt, epr, self.cfg.block_size)
                                .ok_or_else(|| anyhow!("cache budget exceeded"))?;
                            stream.blocks.push(b);
                        }
                        let pushed = stream
                            .blocks
                            .last_mut()
                            .expect("a block was just ensured above")
                            .push_rows(rows);
                        rows = &rows[pushed * epr..];
                    }
                }
            }
        }
        seq.len += n;
        Ok(())
    }

    /// Read back one stream, decoded to f32 into owned buffers (see
    /// `StoredRows`).  Prefer `stream` + `decode_range_into` on hot
    /// paths — it neither clones block data nor re-decodes old rows.
    pub fn stored_rows(&self, id: u64, layer: usize, side: Side) -> Result<StoredRows> {
        Ok(match self.stream(id, layer, side)? {
            StreamRows::Alias => StoredRows::Alias,
            StreamRows::Latent(v) => StoredRows::Latent(v.to_vec()),
            StreamRows::Heads(v, heads) => StoredRows::Heads(v.to_vec(), heads.to_vec()),
        })
    }

    /// Borrowed view of one stream — the zero-copy retrieval API (see
    /// `StreamRows`).  For sequences admitted against a shared prefix
    /// chain the view chains the (full, refcounted) prefix blocks before
    /// the sequence's own suffix blocks, so shared reads are bitwise
    /// identical to what an unshared ingest of the same rows would read.
    pub fn stream(&self, id: u64, layer: usize, side: Side) -> Result<StreamRows<'_>> {
        let seq = self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        anyhow::ensure!(
            !seq.parked,
            "sequence {id} is parked in the host tier (restore before reading)"
        );
        let stream = &seq.streams[layer][side as usize];
        let epr = stream.kind.elements(&self.cfg.spec);
        let blocks = if epr == 0 || seq.prefix_path.is_empty() {
            ViewBlocks::Contiguous(&stream.blocks)
        } else {
            ViewBlocks::Chained {
                index: &self.prefix,
                path: &seq.prefix_path,
                layer,
                side,
                own: &stream.blocks,
            }
        };
        let view = StreamView {
            blocks,
            len: seq.len,
            elements_per_row: epr,
        };
        Ok(match &stream.kind {
            StoreKind::FullAlias => StreamRows::Alias,
            StoreKind::Latent => StreamRows::Latent(view),
            StoreKind::Heads(heads) => StreamRows::Heads(view, heads),
        })
    }

    /// Decode watermark for a sequence: rows [0, watermark) have already
    /// been materialized into effective-cache scratch.
    pub fn decoded_upto(&self, id: u64) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.decoded_upto)
    }

    /// Advance the decode watermark (clamped to the sequence length).
    pub fn mark_decoded(&mut self, id: u64, upto: usize) {
        if let Some(s) = self.seqs.get_mut(&id) {
            s.decoded_upto = upto.min(s.len);
        }
    }

    /// Invalidate the watermark (eviction-resume: the scratch was
    /// dropped, the next retrieval must rebuild from row 0).
    pub fn reset_decoded(&mut self, id: u64) {
        if let Some(s) = self.seqs.get_mut(&id) {
            s.decoded_upto = 0;
        }
    }

    /// Whether a sequence's compressed payload currently lives in the
    /// host tier (blocks freed; reads and appends fail until restored).
    pub fn seq_parked(&self, id: u64) -> bool {
        self.seqs.get(&id).map_or(false, |s| s.parked)
    }

    /// Spill a sequence to the host tier: copy the *actual encoded block
    /// bytes* into the `ParkedBytes` wire format, free every device block
    /// back to the pool (a real memory release, visible in `pool_stats`),
    /// and mark the sequence parked.  The watermark is invalidated — the
    /// effective-cache scratch is the caller's to drop, and resume goes
    /// through a full rebuild.
    ///
    /// Refcount-aware: only the sequence's **own suffix blocks** spill.
    /// A shared prefix chain stays device-resident and referenced (its
    /// other sharers keep reading it), so a parked sharer neither moves
    /// prefix bytes nor risks the chain being freed under it.
    pub fn extract_sequence_bytes(&mut self, id: u64) -> Result<ParkedBytes> {
        let seq = self
            .seqs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        anyhow::ensure!(
            !seq.parked,
            "sequence {id} already parked (double-evict would corrupt tier accounting)"
        );
        let mut payload = Vec::new();
        for pair in seq.streams.iter_mut() {
            for s in pair.iter_mut() {
                for b in &s.blocks {
                    payload.extend_from_slice(b.rows_view(0, b.rows).raw());
                }
                for b in s.blocks.drain(..) {
                    self.pool.free(b);
                }
            }
        }
        seq.parked = true;
        seq.decoded_upto = 0;
        Ok(ParkedBytes {
            len: seq.len,
            prefix_rows: seq.prefix_rows,
            demoted: seq.demoted,
            demoted_spans: seq.demoted_spans.clone(),
            payload,
        })
    }

    /// Fill a parked sequence back from its `ParkedBytes` payload:
    /// reallocate blocks from the pool (budget-checked) and copy the
    /// encoded bytes in verbatim, so the restored store is bit-identical
    /// to the pre-spill store.  On a budget failure nothing is committed
    /// (staged blocks are returned to the pool and the sequence stays
    /// parked).  The watermark stays at 0 — the next retrieval rebuilds
    /// the effective cache in full.
    pub fn restore_sequence_bytes(&mut self, id: u64, parked: &ParkedBytes) -> Result<()> {
        {
            let seq = self
                .seqs
                .get(&id)
                .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
            anyhow::ensure!(seq.parked, "sequence {id} is not parked");
            anyhow::ensure!(
                seq.len == parked.len,
                "parked payload covers {} rows, sequence has {}",
                parked.len,
                seq.len
            );
            anyhow::ensure!(
                seq.prefix_rows == parked.prefix_rows,
                "parked payload assumes {} shared prefix rows, sequence holds {}",
                parked.prefix_rows,
                seq.prefix_rows
            );
        }
        // derive the per-block wire layout from the plan, the adaptive
        // regions, and the payload's own demotion flags (no per-stream
        // or per-block headers travel with the payload); only the
        // suffix rows past the still-resident shared prefix travel
        let own = parked.len - parked.prefix_rows;
        let bs = self.cfg.block_size;
        let layout = self.cfg.own_block_layout(
            parked.len,
            parked.prefix_rows,
            parked.demoted,
            &parked.demoted_spans,
        );
        let block_rows = |b: usize| (own - b * bs).min(bs);
        let total: usize = layout
            .iter()
            .map(|(epr, fmts)| {
                fmts.iter()
                    .enumerate()
                    .map(|(b, f)| block_rows(b) * f.row_bytes(*epr))
                    .sum::<usize>()
            })
            .sum();
        anyhow::ensure!(
            parked.payload.len() == total,
            "parked payload is {} bytes, wire format needs {total}",
            parked.payload.len()
        );
        // stage every block before committing any, so a budget failure
        // mid-way leaves the sequence cleanly parked
        let mut staged: Vec<Vec<Block>> = Vec::with_capacity(layout.len());
        let mut off = 0usize;
        for (epr, fmts) in &layout {
            let mut blocks = Vec::with_capacity(fmts.len());
            for (bi, &fmt) in fmts.iter().enumerate() {
                let nbytes = block_rows(bi) * fmt.row_bytes(*epr);
                let Some(mut b) = self.pool.alloc(fmt, *epr, bs) else {
                    for blks in staged {
                        for blk in blks {
                            self.pool.free(blk);
                        }
                    }
                    for blk in blocks {
                        self.pool.free(blk);
                    }
                    return Err(anyhow!("cache budget exceeded restoring sequence {id}"));
                };
                let taken = b.push_raw_rows(&parked.payload[off..off + nbytes]);
                debug_assert_eq!(taken, block_rows(bi));
                off += nbytes;
                blocks.push(b);
            }
            staged.push(blocks);
        }
        let seq = self
            .seqs
            .get_mut(&id)
            .expect("sequence existence checked above");
        for (i, blocks) in staged.into_iter().enumerate() {
            seq.streams[i / 2][i % 2].blocks = blocks;
        }
        seq.parked = false;
        seq.demoted = parked.demoted;
        seq.demoted_spans = parked.demoted_spans.clone();
        seq.decoded_upto = 0;
        Ok(())
    }

    /// Whether the pressure ladder has demoted this sequence to the int8
    /// rung (false for unknown sequences).
    pub fn seq_demoted(&self, id: u64) -> bool {
        self.seqs.get(&id).map_or(false, |s| s.demoted)
    }

    /// Demote a sequence's own blocks to the cheapest storage rung: every
    /// stored stream not already int8 is decoded and re-encoded as int8
    /// (Eq. 4 per-row quantization), freeing the difference back to the
    /// pool.  The pressure ladder's middle step — lossy (quantization
    /// error on the re-encoded rows) but the sequence stays resident and
    /// decodable, unlike a park.  Shared prefix blocks are untouched:
    /// other sharers read them, so only private suffix bytes get cheaper.
    ///
    /// Staging is all-or-nothing: replacement blocks for every stream are
    /// allocated before any original is freed, so a budget failure
    /// mid-way leaves the sequence exactly as it was (the transient
    /// double-residency is why a demotion can fail under the very
    /// pressure it relieves — the ladder then moves to the park rung).
    /// Idempotent: a demoted sequence returns `Ok(0)`.  The decode
    /// watermark is invalidated — re-encoded rows decode to slightly
    /// different f32s, so stale scratch must not survive the demotion.
    ///
    /// Returns the stored bytes freed (block-capacity granularity).
    pub fn demote_sequence(&mut self, id: u64) -> Result<usize> {
        let spec = self.cfg.spec.clone();
        let bs = self.cfg.block_size;
        let seq = self
            .seqs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        anyhow::ensure!(!seq.parked, "sequence {id} is parked in the host tier");
        if seq.demoted {
            return Ok(0);
        }
        let mut staged: Vec<Option<Vec<Block>>> = Vec::with_capacity(2 * spec.n_layer);
        let mut scratch: Vec<f32> = Vec::new();
        for layer in 0..spec.n_layer {
            for side in 0..2usize {
                let stream = &seq.streams[layer][side];
                let epr = stream.kind.elements(&spec);
                if epr == 0
                    || stream.blocks.is_empty()
                    || stream
                        .blocks
                        .iter()
                        .all(|b| matches!(b.format, Format::Int8))
                {
                    staged.push(None);
                    continue;
                }
                let mut new_blocks: Vec<Block> = Vec::new();
                for b in &stream.blocks {
                    scratch.resize(b.rows * epr, 0.0);
                    b.decode_rows_into(0, b.rows, &mut scratch[..b.rows * epr]);
                    let mut rows: &[f32] = &scratch[..b.rows * epr];
                    while !rows.is_empty() {
                        if new_blocks.last().map_or(true, Block::is_full) {
                            let Some(nb) = self.pool.alloc(Format::Int8, epr, bs) else {
                                for blk in new_blocks {
                                    self.pool.free(blk);
                                }
                                for s in staged.into_iter().flatten() {
                                    for blk in s {
                                        self.pool.free(blk);
                                    }
                                }
                                return Err(anyhow!(
                                    "cache budget exceeded demoting sequence {id}"
                                ));
                            };
                            new_blocks.push(nb);
                        }
                        let pushed = new_blocks
                            .last_mut()
                            .expect("a block was just ensured above")
                            .push_rows(rows);
                        rows = &rows[pushed * epr..];
                    }
                }
                staged.push(Some(new_blocks));
            }
        }
        let mut before = 0usize;
        let mut after = 0usize;
        for (i, slot) in staged.into_iter().enumerate() {
            if let Some(new_blocks) = slot {
                after += new_blocks.iter().map(Block::stored_bytes).sum::<usize>();
                let old = std::mem::replace(&mut seq.streams[i / 2][i % 2].blocks, new_blocks);
                for b in old {
                    before += b.stored_bytes();
                    self.pool.free(b);
                }
            }
        }
        seq.demoted = true;
        seq.decoded_upto = 0;
        Ok(before.saturating_sub(after))
    }

    /// Block-aligned own-row spans the adaptive ladder demoted
    /// regionally (sorted, disjoint; empty for untouched sequences or
    /// unknown ids).
    pub fn seq_demoted_spans(&self, id: u64) -> Vec<(usize, usize)> {
        self.seqs
            .get(&id)
            .map_or_else(Vec::new, |s| s.demoted_spans.clone())
    }

    /// Demote one block-aligned own-row region `[start, end)` to the
    /// int8 rung — the per-region generalization of
    /// [`CacheManager::demote_sequence`] the adaptive ladder uses: only
    /// the region's blocks are decoded and re-encoded int8, the rest of
    /// the sequence keeps its rungs, and the span is recorded in
    /// `demoted_spans` (merged, carried through [`ParkedBytes`]) so
    /// every layout derivation — appends into the span, park/unpark,
    /// delta manifests, the predicted-bytes law — sees the demotion.
    ///
    /// Staging is all-or-nothing exactly like the whole-sequence rung:
    /// a budget failure mid-way leaves the sequence untouched.  Blocks
    /// in the region already int8 (plan, region rung, or an earlier
    /// demotion) are skipped, so re-demoting a span is idempotent and
    /// frees 0.  The decode watermark is clamped to `start` — re-encoded
    /// rows decode to slightly different f32s, so scratch past the
    /// region start must not survive.
    ///
    /// Returns the stored bytes freed (block-capacity granularity).
    pub fn demote_region(&mut self, id: u64, start: usize, end: usize) -> Result<usize> {
        let spec = self.cfg.spec.clone();
        let bs = self.cfg.block_size;
        anyhow::ensure!(
            start < end && start % bs == 0 && end % bs == 0,
            "demotion region [{start}, {end}) must be non-empty and {bs}-row aligned"
        );
        let seq = self
            .seqs
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        anyhow::ensure!(!seq.parked, "sequence {id} is parked in the host tier");
        anyhow::ensure!(
            start >= seq.prefix_rows,
            "region starts at {start}, inside the shared prefix ({} rows) — \
             shared chunks are immutable and cannot be demoted",
            seq.prefix_rows
        );
        let own = seq.len - seq.prefix_rows;
        let n_blocks = own.div_ceil(bs);
        anyhow::ensure!(
            end <= seq.prefix_rows + n_blocks * bs,
            "region ends at {end}, past the sequence's {} stored rows",
            seq.len
        );
        let b0 = (start - seq.prefix_rows) / bs;
        let b1 = (end - seq.prefix_rows) / bs;
        // stage replacement int8 blocks for every non-int8 block in the
        // region before freeing any original (all-or-nothing)
        let mut staged: Vec<(usize, usize, Block)> = Vec::new();
        let mut scratch: Vec<f32> = Vec::new();
        for (si, stream) in seq
            .streams
            .iter()
            .flat_map(|pair| pair.iter())
            .enumerate()
        {
            let epr = stream.kind.elements(&spec);
            if epr == 0 {
                continue;
            }
            for (bi, b) in stream.blocks.iter().enumerate().take(b1).skip(b0) {
                if matches!(b.format, Format::Int8) {
                    continue;
                }
                scratch.resize(b.rows * epr, 0.0);
                b.decode_rows_into(0, b.rows, &mut scratch[..b.rows * epr]);
                let Some(mut nb) = self.pool.alloc(Format::Int8, epr, bs) else {
                    for (_, _, blk) in staged {
                        self.pool.free(blk);
                    }
                    return Err(anyhow!(
                        "cache budget exceeded demoting region of sequence {id}"
                    ));
                };
                let pushed = nb.push_rows(&scratch[..b.rows * epr]);
                debug_assert_eq!(pushed, b.rows);
                staged.push((si, bi, nb));
            }
        }
        let mut before = 0usize;
        let mut after = 0usize;
        for (si, bi, nb) in staged {
            after += nb.stored_bytes();
            let old = std::mem::replace(&mut seq.streams[si / 2][si % 2].blocks[bi], nb);
            before += old.stored_bytes();
            self.pool.free(old);
        }
        merge_span(&mut seq.demoted_spans, start, end);
        seq.decoded_upto = seq.decoded_upto.min(start);
        Ok(before.saturating_sub(after))
    }

    /// The coldest (lowest-index) run of up to `max_blocks` own blocks
    /// still holding a rung above int8, as an absolute row region ready
    /// for [`CacheManager::demote_region`] — `None` when the sequence
    /// is parked, unknown, or already int8 throughout (nothing left for
    /// the regional ladder rung to reclaim).
    pub fn coldest_promotable_region(&self, id: u64, max_blocks: usize) -> Option<(usize, usize)> {
        let seq = self.seqs.get(&id)?;
        if seq.parked {
            return None;
        }
        let bs = self.cfg.block_size;
        let n_blocks = (seq.len - seq.prefix_rows).div_ceil(bs);
        let first = seq
            .streams
            .iter()
            .flat_map(|pair| pair.iter())
            .filter_map(|st| {
                st.blocks
                    .iter()
                    .position(|b| !matches!(b.format, Format::Int8))
            })
            .min()?;
        let last = (first + max_blocks.max(1)).min(n_blocks);
        Some((seq.prefix_rows + first * bs, seq.prefix_rows + last * bs))
    }

    /// Manifest-predicted stored bytes for a live sequence: what the
    /// config's per-block layout says the sequence's own blocks must
    /// cost at block-capacity granularity.  The plan-coherence
    /// invariant (`coordinator/invariants.rs`) asserts this equals
    /// [`CacheManager::seq_stored_bytes`] for every live sequence after
    /// every round — the bytes law that pins measured storage to the
    /// declared policy.  0 for parked or unknown sequences.
    pub fn seq_predicted_bytes(&self, id: u64) -> usize {
        let Some(seq) = self.seqs.get(&id) else {
            return 0;
        };
        if seq.parked {
            return 0;
        }
        let bs = self.cfg.block_size;
        self.cfg
            .own_block_layout(seq.len, seq.prefix_rows, seq.demoted, &seq.demoted_spans)
            .into_iter()
            .map(|(epr, fmts)| {
                fmts.iter()
                    .map(|f| bs * f.row_bytes(epr))
                    .sum::<usize>()
            })
            .sum()
    }

    /// Measured stored bytes for a sequence (block capacity granularity).
    pub fn seq_stored_bytes(&self, id: u64) -> usize {
        self.seqs
            .get(&id)
            .map(|s| {
                s.streams
                    .iter()
                    .flat_map(|pair| pair.iter())
                    .flat_map(|st| st.blocks.iter())
                    .map(Block::stored_bytes)
                    .sum()
            })
            .unwrap_or(0)
    }

    /// What an uncompressed f32 cache would use for the same length.
    pub fn seq_baseline_bytes(&self, id: u64) -> usize {
        let len = self.seq_len(id).unwrap_or(0);
        // round up to block granularity for a like-for-like comparison
        let blocks = len.div_ceil(self.cfg.block_size);
        let spec = &self.cfg.spec;
        2 * spec.n_layer
            * Format::F32.row_bytes(spec.kv_dim())
            * blocks
            * self.cfg.block_size
    }

    /// The plan's per-(layer, head) K/V reuse masks (alias resolution).
    pub fn reuse_masks(&self) -> (&Vec<Vec<bool>>, &Vec<Vec<bool>>) {
        (&self.cfg.plan.reuse_k, &self.cfg.plan.reuse_v)
    }

    // --- cross-request shared-prefix reuse (DESIGN.md §6) -----------------

    /// Reference an empty, freshly-created sequence onto the shared
    /// chain ending at `leaf`: the sequence starts at the chain's
    /// block-aligned row count with **zero own bytes** — its reads chain
    /// through the shared blocks, its appends go to private suffix
    /// blocks.  Fails (without touching refcounts) unless the sequence
    /// is empty, unparked, and unshared.
    pub fn attach_prefix(&mut self, id: u64, leaf: u32) -> Result<()> {
        let bs = self.cfg.block_size;
        let max_seq = self.cfg.spec.max_seq;
        {
            let seq = self
                .seqs
                .get(&id)
                .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
            anyhow::ensure!(!seq.parked, "sequence {id} is parked in the host tier");
            anyhow::ensure!(
                seq.len == 0 && seq.prefix_path.is_empty(),
                "prefix attaches only to empty, unshared sequences"
            );
        }
        let path = self.prefix.attach(leaf)?;
        let rows = path.len() * bs;
        debug_assert!(rows <= max_seq, "prefix chain exceeds max_seq");
        let seq = self
            .seqs
            .get_mut(&id)
            .expect("sequence existence checked above");
        seq.prefix_path = path;
        seq.prefix_rows = rows;
        seq.len = rows;
        Ok(())
    }

    /// Ingest one prefill lane's prompt rows into an empty sequence,
    /// sharing every block-aligned leading chunk through the prefix
    /// trie: chunks another admission already stored are **referenced,
    /// not re-stored** (`reused_rows`), new chunks are encoded once into
    /// immutable shared blocks, and the unshared tail rows
    /// `[prefix_rows, plen)` append to the sequence's private blocks.
    ///
    /// `toks` is the clamped prompt (`plen = toks.len()` rows); the
    /// buffers are prefill-lane shaped (`[L, stride, *]`, absolute token
    /// indexing) exactly as `append_rows` takes them.  Shared chunk
    /// blocks are encoded through the same codecs as a private append,
    /// so a sharer's stream reads are bitwise identical to an unshared
    /// ingest of the same lane.  On any failure (e.g. pool budget) every
    /// chunk this call created is rolled back and the sequence is left
    /// empty or partially appended for the caller to free.
    #[allow(clippy::too_many_arguments)]
    pub fn ingest_prompt_shared(
        &mut self,
        id: u64,
        toks: &[u8],
        stride: usize,
        k_lat: &[f32],
        v_lat: &[f32],
        k_raw: &[f32],
        v_raw: &[f32],
    ) -> Result<SharedIngest> {
        let plen = toks.len();
        let bs = self.cfg.block_size;
        {
            let seq = self
                .seqs
                .get(&id)
                .ok_or_else(|| anyhow!("unknown sequence {id}"))?;
            anyhow::ensure!(!seq.parked, "sequence {id} is parked in the host tier");
            anyhow::ensure!(
                seq.len == 0 && seq.prefix_path.is_empty(),
                "shared ingest needs an empty, unshared sequence"
            );
        }
        anyhow::ensure!(plen <= stride, "prompt exceeds buffer stride");
        anyhow::ensure!(plen <= self.cfg.spec.max_seq, "prompt exceeds max_seq");
        {
            let (l, dl, kvd) = (
                self.cfg.spec.n_layer,
                self.cfg.spec.ae_latent,
                self.cfg.spec.kv_dim(),
            );
            anyhow::ensure!(
                k_lat.len() == l * stride * dl && v_lat.len() == l * stride * dl,
                "latent shape"
            );
            anyhow::ensure!(
                k_raw.len() == l * stride * kvd && v_raw.len() == l * stride * kvd,
                "raw shape"
            );
        }

        let n_chunks = plen / bs;
        let mut parent: Option<u32> = None;
        let mut reused_rows = 0usize;
        let mut created: Vec<u32> = Vec::new();
        for i in 0..n_chunks {
            let key = &toks[i * bs..(i + 1) * bs];
            if let Some(child) = self.prefix.child(parent, key) {
                self.prefix.stats.chunk_hits += 1;
                reused_rows += bs;
                parent = Some(child);
                continue;
            }
            match self.build_chunk_blocks(i * bs, bs, stride, k_lat, v_lat, k_raw, v_raw) {
                Ok((blocks, bytes)) => {
                    self.prefix.stats.chunk_misses += 1;
                    let node = self.prefix.add_child(parent, key.to_vec(), blocks, bytes);
                    created.push(node);
                    parent = Some(node);
                }
                Err(e) => {
                    // roll the new chunks back leaf-first; chunks that
                    // pre-existed keep their other references untouched
                    for &node in created.iter().rev() {
                        self.prefix.remove_unreferenced(node, &mut self.pool);
                    }
                    return Err(e);
                }
            }
        }
        let prefix_rows = n_chunks * bs;
        if let Some(leaf) = parent {
            self.attach_prefix(id, leaf)?;
        }
        self.append_range(id, prefix_rows, plen, stride, k_lat, v_lat, k_raw, v_raw)?;
        self.prefix.stats.reused_rows += reused_rows as u64;
        Ok(SharedIngest {
            prefix_rows,
            reused_rows,
            leaf: parent,
        })
    }

    /// Encode rows `[from, from + n)` of a prefill lane's buffers into
    /// one full block per stored stream — the payload of one shared
    /// prefix chunk.  Uses exactly the `append_range` gather + codec
    /// path, which is what keeps shared reads bitwise equal to private
    /// ones.  Frees everything staged if the pool budget runs out.
    #[allow(clippy::too_many_arguments)]
    fn build_chunk_blocks(
        &mut self,
        from: usize,
        n: usize,
        stride: usize,
        k_lat: &[f32],
        v_lat: &[f32],
        k_raw: &[f32],
        v_raw: &[f32],
    ) -> Result<(Vec<[Option<Block>; 2]>, usize)> {
        let spec = self.cfg.spec.clone();
        let (l, dl, kvd, dh) = (spec.n_layer, spec.ae_latent, spec.kv_dim(), spec.d_head);
        let mut out: Vec<[Option<Block>; 2]> = Vec::with_capacity(l);
        let mut bytes = 0usize;
        let mut gather: Vec<f32> = Vec::new();
        for layer in 0..l {
            let mut pair: [Option<Block>; 2] = [None, None];
            for (side_idx, side, lat, raw) in [
                (0usize, Side::K, k_lat, k_raw),
                (1, Side::V, v_lat, v_raw),
            ] {
                let kind = self.cfg.store_kind(layer, side);
                let epr = kind.elements(&spec);
                if epr == 0 {
                    continue;
                }
                let fmt = self.cfg.format_for(&kind);
                let rows = gather_stream_rows(
                    &kind,
                    layer,
                    from,
                    from + n,
                    stride,
                    (dl, kvd, dh),
                    lat,
                    raw,
                    &mut gather,
                )
                .expect("stored stream gathers rows");
                let Some(mut b) = self.pool.alloc(fmt, epr, self.cfg.block_size) else {
                    for mut p in out {
                        for blk in p.iter_mut() {
                            if let Some(blk) = blk.take() {
                                self.pool.free(blk);
                            }
                        }
                    }
                    for blk in pair.iter_mut() {
                        if let Some(blk) = blk.take() {
                            self.pool.free(blk);
                        }
                    }
                    return Err(anyhow!("cache budget exceeded storing a shared prefix chunk"));
                };
                let pushed = b.push_rows(rows);
                debug_assert_eq!(pushed, n, "chunk block must fill exactly");
                bytes += b.stored_bytes();
                pair[side_idx] = Some(b);
            }
            out.push(pair);
        }
        Ok((out, bytes))
    }

    /// Pin the chain ending at `leaf` (admission-template hold): the
    /// chain stays warm for zero-launch re-admission even while no
    /// sequence references it.  Balanced by [`CacheManager::prefix_unref`].
    pub fn prefix_ref(&mut self, leaf: u32) -> Result<()> {
        self.prefix.pin(leaf)
    }

    /// Release a pin taken with [`CacheManager::prefix_ref`], recycling
    /// any chunk nothing references any more.
    pub fn prefix_unref(&mut self, leaf: u32) {
        self.prefix.unpin(leaf, &mut self.pool);
    }

    /// Rows a sequence serves from the shared prefix store (0 = unshared).
    pub fn seq_prefix_rows(&self, id: u64) -> usize {
        self.seqs.get(&id).map_or(0, |s| s.prefix_rows)
    }

    /// Shared-chain bytes a sequence reads through (held once in the
    /// prefix store no matter how many sequences share them; the
    /// private counterpart is `seq_stored_bytes`).
    pub fn seq_shared_bytes(&self, id: u64) -> usize {
        self.seqs
            .get(&id)
            .map(|s| {
                s.prefix_path
                    .iter()
                    .map(|&n| self.prefix.node_bytes(n))
                    .sum()
            })
            .unwrap_or(0)
    }

    /// Shared-prefix store accounting snapshot (nodes, hit/miss
    /// counters, bytes held once).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.stats
    }

    /// Re-derive every prefix refcount from the live sequences plus the
    /// caller's pinned leaves and cross-check the trie — the invariant
    /// the admit/park/resume/retire property test asserts after every
    /// step (leak or double-free ⇒ `Err`).
    pub fn prefix_integrity(&self, pinned_leaves: &[u32]) -> Result<(), String> {
        let paths: Vec<&[u32]> = self
            .seqs
            .values()
            .filter(|s| !s.prefix_path.is_empty())
            .map(|s| s.prefix_path.as_slice())
            .collect();
        self.prefix.integrity(&paths, pinned_leaves)
    }

    // --- cross-worker migration (DESIGN.md §10) ---------------------------

    /// Leaf node of a sequence's shared prefix chain (`None` when the
    /// sequence shares nothing) — the handle migration uses to
    /// enumerate and re-create the chain on another worker.
    pub fn seq_prefix_leaf(&self, id: u64) -> Option<u32> {
        self.seqs.get(&id).and_then(|s| s.prefix_path.last().copied())
    }

    /// Node ids of the chain root→`leaf` — the walk
    /// [`CacheManager::export_chunk`] and chunk-delivery rollback
    /// enumerate with (pairs up index-for-index with
    /// [`CacheManager::prefix_chain`]).
    pub fn prefix_path(&self, leaf: u32) -> Result<Vec<u32>> {
        self.prefix.path(leaf)
    }

    /// Look up the trie child holding `key` under `parent` (`None` =
    /// a root chunk).  Migration uses this to skip exporting chunk
    /// payloads the destination already stores — whether delivered by
    /// an earlier transfer or built by its own admissions.
    pub fn prefix_child(&self, parent: Option<u32>, key: &[u8]) -> Option<u32> {
        self.prefix.child(parent, key)
    }

    /// Free one unreferenced, childless chunk — the rollback of a
    /// chunk delivery that failed partway down its chain (imported
    /// nodes are removed deepest-first so none ever has children left).
    pub fn remove_unreferenced_chunk(&mut self, node: u32) {
        self.prefix.remove_unreferenced(node, &mut self.pool);
    }

    /// Content-addressed descriptors of the chain root→`leaf`: one
    /// `(chain id, token key)` per chunk, root first.  The chain id is
    /// [`chunk_chain_id`] over the parent's id and the chunk's own
    /// token key, so equal token prefixes hash to equal ids on every
    /// worker with no coordination — the property that lets a router
    /// ship each shared chunk to a worker at most once, ever.
    pub fn prefix_chain(&self, leaf: u32) -> Result<Vec<(u64, Vec<u8>)>> {
        let mut chain = Vec::new();
        let mut parent_id = 0u64;
        for node in self.prefix.path(leaf)? {
            let key = self.prefix.key(node)?.to_vec();
            let id = chunk_chain_id(parent_id, &key);
            chain.push((id, key));
            parent_id = id;
        }
        Ok(chain)
    }

    /// Export one shared-prefix chunk's payload: the encoded bytes of
    /// its full block per byte-bearing stream, wire order (the same
    /// layer-ascending, K-before-V order as [`ParkedBytes`]).  Shared
    /// chunks are never demoted, so the formats are the plan's own.
    pub fn export_chunk(&self, node: u32) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::new();
        for layer in 0..self.cfg.spec.n_layer {
            for side in [Side::K, Side::V] {
                let kind = self.cfg.store_kind(layer, side);
                if kind.elements(&self.cfg.spec) == 0 {
                    continue;
                }
                let b = self.prefix.block(node, layer, side).ok_or_else(|| {
                    anyhow!("prefix chunk {node} is missing a stored stream block")
                })?;
                out.push(b.rows_view(0, b.rows).raw().to_vec());
            }
        }
        Ok(out)
    }

    /// Import one content-addressed chunk under `parent` from an
    /// [`CacheManager::export_chunk`] payload.  Idempotent: an existing
    /// child under the same key is returned untouched (the payload is
    /// ignored — content addressing guarantees it holds the same
    /// bytes).  Staging is all-or-nothing: a budget failure frees every
    /// staged block and leaves the trie unchanged.
    pub fn import_chunk(
        &mut self,
        parent: Option<u32>,
        key: &[u8],
        streams: &[Vec<u8>],
    ) -> Result<u32> {
        if let Some(existing) = self.prefix.child(parent, key) {
            self.prefix.stats.chunk_hits += 1;
            return Ok(existing);
        }
        let bs = self.cfg.block_size;
        anyhow::ensure!(key.len() == bs, "chunk key must span one block of tokens");
        let spec = self.cfg.spec.clone();
        let mut blocks: Vec<[Option<Block>; 2]> = Vec::with_capacity(spec.n_layer);
        let mut bytes = 0usize;
        let mut payloads = streams.iter();
        for layer in 0..spec.n_layer {
            let mut pair: [Option<Block>; 2] = [None, None];
            for (side_idx, side) in [(0usize, Side::K), (1, Side::V)] {
                let kind = self.cfg.store_kind(layer, side);
                let epr = kind.elements(&spec);
                if epr == 0 {
                    continue;
                }
                let fmt = self.cfg.format_for(&kind);
                let Some(raw) = payloads.next() else {
                    for mut p in blocks {
                        for blk in p.iter_mut().filter_map(Option::take) {
                            self.pool.free(blk);
                        }
                    }
                    for blk in pair.iter_mut().filter_map(Option::take) {
                        self.pool.free(blk);
                    }
                    return Err(anyhow!("chunk payload is missing a stored stream"));
                };
                if raw.len() != bs * fmt.row_bytes(epr) {
                    let got = raw.len();
                    let want = bs * fmt.row_bytes(epr);
                    for mut p in blocks {
                        for blk in p.iter_mut().filter_map(Option::take) {
                            self.pool.free(blk);
                        }
                    }
                    for blk in pair.iter_mut().filter_map(Option::take) {
                        self.pool.free(blk);
                    }
                    return Err(anyhow!(
                        "chunk stream payload is {got} bytes, layout derives {want}"
                    ));
                }
                let Some(mut b) = self.pool.alloc(fmt, epr, bs) else {
                    for mut p in blocks {
                        for blk in p.iter_mut().filter_map(Option::take) {
                            self.pool.free(blk);
                        }
                    }
                    for blk in pair.iter_mut().filter_map(Option::take) {
                        self.pool.free(blk);
                    }
                    return Err(anyhow!(
                        "cache budget exceeded importing a shared prefix chunk"
                    ));
                };
                let taken = b.push_raw_rows(raw);
                debug_assert_eq!(taken, bs, "chunk block must fill exactly");
                bytes += b.stored_bytes();
                pair[side_idx] = Some(b);
            }
            blocks.push(pair);
        }
        anyhow::ensure!(
            payloads.next().is_none(),
            "chunk payload carries extra streams"
        );
        self.prefix.stats.chunk_misses += 1;
        Ok(self.prefix.add_child(parent, key.to_vec(), blocks, bytes))
    }

    /// Create the destination-side shell of a migrated sequence: a
    /// fresh id covering `len` rows over the chain ending at `leaf`,
    /// registered **parked** so the very next step is
    /// [`CacheManager::restore_sequence_bytes`] with the transferred
    /// payload.  `demoted`/`demoted_spans` mirror the transferred
    /// [`ParkedBytes`] flags so the shell already reflects the rungs
    /// the payload was encoded under.  On failure nothing is left
    /// behind.
    pub fn import_sequence(
        &mut self,
        len: usize,
        leaf: Option<u32>,
        demoted: bool,
        demoted_spans: &[(usize, usize)],
    ) -> Result<u64> {
        anyhow::ensure!(
            len <= self.cfg.spec.max_seq,
            "imported sequence of {len} rows exceeds max_seq"
        );
        let id = self.create_sequence();
        if let Some(leaf) = leaf {
            if let Err(e) = self.attach_prefix(id, leaf) {
                self.free_sequence(id);
                return Err(e);
            }
        }
        let prefix_rows = self.seq_prefix_rows(id);
        if prefix_rows > len {
            self.free_sequence(id);
            return Err(anyhow!(
                "imported length {len} is shorter than its {prefix_rows} shared prefix rows"
            ));
        }
        let seq = self
            .seqs
            .get_mut(&id)
            .expect("sequence created a few lines up");
        seq.len = len;
        seq.demoted = demoted;
        seq.demoted_spans = demoted_spans.to_vec();
        seq.parked = true;
        seq.decoded_upto = 0;
        Ok(id)
    }
}

/// FNV-1a chain hash giving every shared-prefix chunk a **content
/// address**: the id of a chunk is a pure function of its ancestors'
/// token keys and its own, so two workers that ingested the same
/// prompt prefix independently derive the same ids — the coordination-
/// free identity cross-worker migration dedups chunk transfers by.
pub fn chunk_chain_id(parent: u64, key: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in parent.to_le_bytes().iter().chain(key) {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Gather the encodable rows of one (layer, side) stream for buffer
/// rows `[from, to)` out of prefill-shaped `[L, stride, *]` buffers.
/// This is the **one** gather path both private appends
/// (`append_range`) and shared prefix chunks (`build_chunk_blocks`)
/// encode through — sharing it is what keeps shared-chunk reads
/// bitwise-equal to private ones by construction, not by parallel
/// maintenance.  Returns `None` for fully-aliased streams; `Heads`
/// rows are gathered into `scratch`.  `dims` is `(dl, kvd, dh)`.
#[allow(clippy::too_many_arguments)]
fn gather_stream_rows<'a>(
    kind: &StoreKind,
    layer: usize,
    from: usize,
    to: usize,
    stride: usize,
    dims: (usize, usize, usize),
    lat: &'a [f32],
    raw: &'a [f32],
    scratch: &'a mut Vec<f32>,
) -> Option<&'a [f32]> {
    let (dl, kvd, dh) = dims;
    let n = to - from;
    match kind {
        StoreKind::FullAlias => None,
        StoreKind::Latent => {
            let base = layer * stride * dl + from * dl;
            Some(&lat[base..base + n * dl])
        }
        StoreKind::Heads(heads) => {
            scratch.clear();
            scratch.reserve(n * heads.len() * dh);
            for t in from..to {
                for &h in heads {
                    let base = layer * stride * kvd + t * kvd + h * dh;
                    scratch.extend_from_slice(&raw[base..base + dh]);
                }
            }
            Some(scratch.as_slice())
        }
    }
}

/// What one shared-prefix ingest did (see
/// [`CacheManager::ingest_prompt_shared`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SharedIngest {
    /// leading rows now served through the shared chain (block-aligned)
    pub prefix_rows: usize,
    /// of those, rows that already existed in the store (referenced
    /// instead of re-stored — the cross-request byte dedup)
    pub reused_rows: usize,
    /// leaf node of the chain (None when the prompt is shorter than one
    /// block — nothing to share at block granularity)
    pub leaf: Option<u32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::memory::{kv_bytes_per_token, CompressionPlan};
    use crate::model::{Arch, ModelSpec};
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "test".into(),
            arch: Arch::Gpt2,
            vocab: 256,
            n_layer: 4,
            d_model: 32,
            n_head: 4,
            n_kv_head: 4,
            d_head: 8,
            ffn_dim: 64,
            max_seq: 64,
            ae_hidden: 24,
            ae_latent: 16,
            bytes_per_el: 4,
        }
    }

    fn rand_rows(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    fn append_n(m: &mut CacheManager, id: u64, n: usize, rng: &mut Rng) {
        let spec = m.cfg.spec.clone();
        for _ in 0..n {
            let kl = rand_rows(rng, spec.n_layer * spec.ae_latent);
            let vl = rand_rows(rng, spec.n_layer * spec.ae_latent);
            let kr = rand_rows(rng, spec.n_layer * spec.kv_dim());
            let vr = rand_rows(rng, spec.n_layer * spec.kv_dim());
            m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
        }
    }

    #[test]
    fn baseline_roundtrip_exact() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(0);
        let kr = rand_rows(&mut rng, spec.n_layer * spec.kv_dim());
        let dummy_lat = vec![0.0; spec.n_layer * spec.ae_latent];
        m.append_token(id, &dummy_lat, &dummy_lat, &kr, &kr).unwrap();
        match m.stored_rows(id, 2, Side::K).unwrap() {
            StoredRows::Heads(rows, heads) => {
                assert_eq!(heads, vec![0, 1, 2, 3]);
                assert_eq!(rows, kr[2 * spec.kv_dim()..3 * spec.kv_dim()].to_vec());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn latent_layers_store_latents() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 2);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(1);
        let kl = rand_rows(&mut rng, spec.n_layer * spec.ae_latent);
        let zeros_raw = vec![0.0; spec.n_layer * spec.kv_dim()];
        m.append_token(id, &kl, &kl, &zeros_raw, &zeros_raw).unwrap();
        match m.stored_rows(id, 0, Side::K).unwrap() {
            StoredRows::Latent(rows) => {
                assert_eq!(rows, kl[..spec.ae_latent].to_vec());
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            m.stored_rows(id, 3, Side::K).unwrap(),
            StoredRows::Heads(_, _)
        ));
    }

    #[test]
    fn fully_reused_layer_stores_nothing() {
        let spec = tiny_spec();
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        plan.reuse_k[1] = vec![true; 4];
        plan.reuse_v[1] = vec![true; 4];
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
        let id = m.create_sequence();
        let mut rng = Rng::new(2);
        append_n(&mut m, id, 16, &mut rng);
        assert!(matches!(
            m.stored_rows(id, 1, Side::K).unwrap(),
            StoredRows::Alias
        ));
        // measured == modeled (block-aligned length)
        let measured = m.seq_stored_bytes(id);
        let modeled = kv_bytes_per_token(&spec, &plan) * 16;
        assert_eq!(measured, modeled);
    }

    #[test]
    fn partial_head_reuse_stores_subset() {
        let spec = tiny_spec();
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        plan.reuse_k[2][1] = true;
        plan.reuse_k[2][3] = true;
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(3);
        let kr = rand_rows(&mut rng, spec.n_layer * spec.kv_dim());
        let lat = vec![0.0; spec.n_layer * spec.ae_latent];
        m.append_token(id, &lat, &lat, &kr, &kr).unwrap();
        match m.stored_rows(id, 2, Side::K).unwrap() {
            StoredRows::Heads(rows, heads) => {
                assert_eq!(heads, vec![0, 2]);
                let dh = spec.d_head;
                let base = 2 * spec.kv_dim();
                assert_eq!(&rows[..dh], &kr[base..base + dh]);
                assert_eq!(&rows[dh..2 * dh], &kr[base + 2 * dh..base + 3 * dh]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn measured_savings_match_model_accounting() {
        // across random plans, measured block bytes == Eq.3 generalized
        // accounting at block-aligned lengths
        check(25, |rng| {
            let spec = tiny_spec();
            let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
            for l in 0..spec.n_layer {
                plan.ae_layers[l] = rng.bool(0.4);
                if l > 0 {
                    for h in 0..spec.n_kv_head {
                        plan.reuse_k[l][h] = rng.bool(0.25);
                        plan.reuse_v[l][h] = rng.bool(0.25);
                    }
                }
            }
            plan.quant_int8 = rng.bool(0.5);
            let mut spec4 = spec.clone();
            spec4.bytes_per_el = 4; // modeled f32 to match runtime store
            let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
            let id = m.create_sequence();
            let n = m.cfg.block_size * rng.range(1, 4);
            append_n(&mut m, id, n, rng);
            let measured = m.seq_stored_bytes(id);
            let modeled = kv_bytes_per_token(&spec4, &plan) * n;
            prop_assert!(
                measured == modeled,
                "measured {measured} != modeled {modeled} (plan {plan:?})"
            );
            Ok(())
        });
    }

    #[test]
    fn config_bytes_per_token_matches_eq3_for_f32() {
        check(20, |rng| {
            let spec = tiny_spec();
            let plan = CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head);
            let cfg = CacheConfig::new(spec.clone(), plan.clone());
            // f32 raw rows: the runtime accounting equals the Eq. 3 model
            prop_assert!(
                cfg.raw_format == Format::F32,
                "CacheConfig::new must default to f32 raw rows"
            );
            let modeled = kv_bytes_per_token(&spec, &plan);
            prop_assert!(
                cfg.bytes_per_token() == modeled,
                "runtime {} != modeled {modeled}",
                cfg.bytes_per_token()
            );
            // f16 raw rows never cost more, and cost less whenever any
            // non-int8 raw stream exists
            let mut f16 = cfg.clone();
            f16.raw_format = Format::F16;
            prop_assert!(f16.bytes_per_token() <= modeled, "f16 must not grow rows");
            Ok(())
        });
    }

    #[test]
    fn free_sequence_releases_everything() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 4);
        let mut m = CacheManager::new(CacheConfig::new(spec, plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(5);
        append_n(&mut m, id, 40, &mut rng);
        assert!(m.pool_stats().live_bytes > 0);
        m.free_sequence(id);
        assert_eq!(m.pool_stats().live_bytes, 0);
        assert!(m.pool_stats().free_bytes > 0);
        assert!(m.stored_rows(id, 0, Side::K).is_err());
    }

    #[test]
    fn budget_rejects_overflow() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut m = CacheManager::with_budget(CacheConfig::new(spec, plan), 4096);
        let id = m.create_sequence();
        let mut rng = Rng::new(6);
        let mut appended = 0;
        loop {
            let spec = m.cfg.spec.clone();
            let kl = rand_rows(&mut rng, spec.n_layer * spec.ae_latent);
            let kr = rand_rows(&mut rng, spec.n_layer * spec.kv_dim());
            match m.append_token(id, &kl, &kl, &kr, &kr) {
                Ok(()) => appended += 1,
                Err(e) => {
                    assert!(e.to_string().contains("budget"));
                    break;
                }
            }
            assert!(appended < 1000, "budget never enforced");
        }
    }

    #[test]
    fn max_seq_enforced() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(7);
        append_n(&mut m, id, spec.max_seq, &mut rng);
        let kl = vec![0.0; spec.n_layer * spec.ae_latent];
        let kr = vec![0.0; spec.n_layer * spec.kv_dim()];
        assert!(m.append_token(id, &kl, &kl, &kr, &kr).is_err());
    }

    fn random_plan(rng: &mut Rng, spec: &ModelSpec) -> CompressionPlan {
        CompressionPlan::random(rng, spec.n_layer, spec.n_kv_head)
    }

    #[test]
    fn stream_view_matches_stored_rows_bitwise() {
        check(20, |rng| {
            let spec = tiny_spec();
            let plan = random_plan(rng, &spec);
            let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
            let id = m.create_sequence();
            let n = rng.range(1, 50);
            append_n(&mut m, id, n, rng);
            for layer in 0..spec.n_layer {
                for side in [Side::K, Side::V] {
                    let owned = m.stored_rows(id, layer, side).unwrap();
                    match (owned, m.stream(id, layer, side).unwrap()) {
                        (StoredRows::Alias, StreamRows::Alias) => {}
                        (StoredRows::Latent(rows), StreamRows::Latent(view)) => {
                            prop_assert!(view.len() == n && rows.len() == n * view.elements_per_row());
                            let viewed = view.to_vec();
                            for (a, b) in rows.iter().zip(&viewed) {
                                prop_assert!(a.to_bits() == b.to_bits(), "latent diverges");
                            }
                        }
                        (StoredRows::Heads(rows, heads), StreamRows::Heads(view, h2)) => {
                            prop_assert!(heads == h2, "head sets diverge");
                            let viewed = view.to_vec();
                            for (a, b) in rows.iter().zip(&viewed) {
                                prop_assert!(a.to_bits() == b.to_bits(), "heads diverge");
                            }
                        }
                        other => return Err(format!("kind mismatch {:?}", other.0)),
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chunked_range_decode_matches_full() {
        // the incremental-retrieval invariant: decoding [0,n) in random
        // watermark-sized chunks equals one full-range decode, bitwise
        check(20, |rng| {
            let spec = tiny_spec();
            let plan = random_plan(rng, &spec);
            let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
            let id = m.create_sequence();
            let n = rng.range(2, 50);
            append_n(&mut m, id, n, rng);
            for layer in 0..spec.n_layer {
                for side in [Side::K, Side::V] {
                    let view = match m.stream(id, layer, side).unwrap() {
                        StreamRows::Alias => continue,
                        StreamRows::Latent(v) => v,
                        StreamRows::Heads(v, _) => v,
                    };
                    let epr = view.elements_per_row();
                    let full = view.to_vec();
                    let mut chunked = vec![0.0f32; n * epr];
                    let mut at = 0;
                    while at < n {
                        let to = rng.range(at, n) + 1;
                        view.decode_range_into(at, to, &mut chunked[at * epr..to * epr]);
                        at = to;
                    }
                    for (a, b) in full.iter().zip(&chunked) {
                        prop_assert!(a.to_bits() == b.to_bits(), "chunked decode diverges");
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bulk_append_rows_matches_per_token_appends() {
        check(15, |rng| {
            let spec = tiny_spec();
            let plan = random_plan(rng, &spec);
            let (l, dl, kvd) = (spec.n_layer, spec.ae_latent, spec.kv_dim());
            let n = rng.range(1, spec.max_seq);
            // prefill-shaped buffers [L, n, *]
            let kl = rand_rows(rng, l * n * dl);
            let vl = rand_rows(rng, l * n * dl);
            let kr = rand_rows(rng, l * n * kvd);
            let vr = rand_rows(rng, l * n * kvd);
            let mut bulk = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
            let bid = bulk.create_sequence();
            bulk.append_rows(bid, n, n, &kl, &vl, &kr, &vr).unwrap();
            let mut scalar = CacheManager::new(CacheConfig::new(spec.clone(), plan));
            let sid = scalar.create_sequence();
            let (mut tkl, mut tvl) = (vec![0.0; l * dl], vec![0.0; l * dl]);
            let (mut tkr, mut tvr) = (vec![0.0; l * kvd], vec![0.0; l * kvd]);
            for t in 0..n {
                for layer in 0..l {
                    tkl[layer * dl..][..dl].copy_from_slice(&kl[layer * n * dl + t * dl..][..dl]);
                    tvl[layer * dl..][..dl].copy_from_slice(&vl[layer * n * dl + t * dl..][..dl]);
                    tkr[layer * kvd..][..kvd]
                        .copy_from_slice(&kr[layer * n * kvd + t * kvd..][..kvd]);
                    tvr[layer * kvd..][..kvd]
                        .copy_from_slice(&vr[layer * n * kvd + t * kvd..][..kvd]);
                }
                scalar.append_token(sid, &tkl, &tvl, &tkr, &tvr).unwrap();
            }
            prop_assert!(bulk.seq_len(bid) == scalar.seq_len(sid));
            prop_assert!(
                bulk.seq_stored_bytes(bid) == scalar.seq_stored_bytes(sid),
                "stored bytes diverge"
            );
            for layer in 0..l {
                for side in [Side::K, Side::V] {
                    let a = bulk.stored_rows(bid, layer, side).unwrap();
                    let b = scalar.stored_rows(sid, layer, side).unwrap();
                    let rows = |x: &StoredRows| match x {
                        StoredRows::Alias => Vec::new(),
                        StoredRows::Latent(r) => r.clone(),
                        StoredRows::Heads(r, _) => r.clone(),
                    };
                    let (ra, rb) = (rows(&a), rows(&b));
                    prop_assert!(ra.len() == rb.len(), "row count diverges");
                    for (x, y) in ra.iter().zip(&rb) {
                        prop_assert!(x.to_bits() == y.to_bits(), "bulk append diverges");
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn raw_views_expose_exact_encoded_bytes() {
        // the zero-copy raw path (tier transfer without decode): the
        // per-block views must cover the range exactly and decode to the
        // same values as the f32 range decode
        check(15, |rng| {
            let spec = tiny_spec();
            let plan = random_plan(rng, &spec);
            let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
            let id = m.create_sequence();
            let n = rng.range(2, 50);
            append_n(&mut m, id, n, rng);
            for layer in 0..spec.n_layer {
                for side in [Side::K, Side::V] {
                    let view = match m.stream(id, layer, side).unwrap() {
                        StreamRows::Alias => continue,
                        StreamRows::Latent(v) => v,
                        StreamRows::Heads(v, _) => v,
                    };
                    let epr = view.elements_per_row();
                    let start = rng.range(0, n);
                    let end = rng.range(start, n) + 1;
                    let views = view.raw_views(start, end);
                    let rows: usize = views.iter().map(|v| v.rows).sum();
                    prop_assert!(rows == end - start, "raw views must cover the range");
                    // decoding the raw views piecewise == range decode
                    let mut piecewise = Vec::with_capacity((end - start) * epr);
                    for v in &views {
                        let mut part = vec![0.0f32; v.rows * epr];
                        v.decode_into(&mut part);
                        prop_assert!(
                            v.raw().len() == v.rows * v.format.row_bytes(epr),
                            "raw byte length mismatch"
                        );
                        piecewise.extend_from_slice(&part);
                    }
                    let mut ranged = vec![0.0f32; (end - start) * epr];
                    view.decode_range_into(start, end, &mut ranged);
                    for (a, b) in piecewise.iter().zip(&ranged) {
                        prop_assert!(a.to_bits() == b.to_bits(), "raw views diverge");
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn watermark_tracks_and_clamps() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut m = CacheManager::new(CacheConfig::new(spec, plan));
        let id = m.create_sequence();
        assert_eq!(m.decoded_upto(id), Some(0));
        let mut rng = Rng::new(17);
        append_n(&mut m, id, 10, &mut rng);
        assert_eq!(m.decoded_upto(id), Some(0)); // appends do not decode
        m.mark_decoded(id, 7);
        assert_eq!(m.decoded_upto(id), Some(7));
        m.mark_decoded(id, 99); // clamped to len
        assert_eq!(m.decoded_upto(id), Some(10));
        m.reset_decoded(id);
        assert_eq!(m.decoded_upto(id), Some(0));
        assert_eq!(m.decoded_upto(12345), None);
    }

    #[test]
    fn extract_restore_roundtrips_bitwise_and_releases_pool() {
        // the encoded-byte tier transfer contract: spill moves the real
        // block bytes out (freeing device pool budget), restore brings
        // back a bit-identical store — across every plan kind and format
        check(25, |rng| {
            let spec = tiny_spec();
            let plan = random_plan(rng, &spec);
            let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
            let id = m.create_sequence();
            let n = rng.range(1, 50);
            append_n(&mut m, id, n, rng);
            let before_bytes = m.seq_stored_bytes(id);
            let mut before = Vec::new();
            for layer in 0..spec.n_layer {
                for side in [Side::K, Side::V] {
                    before.push(format!("{:?}", m.stored_rows(id, layer, side).unwrap()));
                }
            }
            let live_before = m.pool_stats().live_bytes;

            let parked = m.extract_sequence_bytes(id).map_err(|e| e.to_string())?;
            prop_assert!(m.seq_parked(id), "sequence must report parked");
            prop_assert!(parked.len == n);
            prop_assert!(
                m.pool_stats().live_bytes + before_bytes == live_before,
                "spill must free the sequence's device blocks"
            );
            // payload is pure encoded rows: no block padding travels
            let expected: usize = (0..spec.n_layer)
                .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
                .map(|(l, s)| {
                    let kind = m.cfg.store_kind(l, s);
                    let epr = kind.elements(&spec);
                    if epr == 0 {
                        0
                    } else {
                        n * m.cfg.format_for(&kind).row_bytes(epr)
                    }
                })
                .sum();
            prop_assert!(
                parked.payload.len() == expected,
                "wire payload {} != expected {expected}",
                parked.payload.len()
            );
            // parked reads and appends fail loudly
            prop_assert!(m.stored_rows(id, 0, Side::K).is_err(), "parked read must fail");
            prop_assert!(m.seq_stored_bytes(id) == 0, "parked sequence holds no device bytes");
            let zl = vec![0.0; spec.n_layer * spec.ae_latent];
            let zr = vec![0.0; spec.n_layer * spec.kv_dim()];
            prop_assert!(
                m.append_token(id, &zl, &zl, &zr, &zr).is_err(),
                "parked append must fail"
            );
            // double-extract rejected
            prop_assert!(m.extract_sequence_bytes(id).is_err());

            m.restore_sequence_bytes(id, &parked).map_err(|e| e.to_string())?;
            prop_assert!(!m.seq_parked(id));
            prop_assert!(m.restore_sequence_bytes(id, &parked).is_err(), "not parked anymore");
            prop_assert!(
                m.seq_stored_bytes(id) == before_bytes,
                "restored block accounting diverges"
            );
            prop_assert!(m.decoded_upto(id) == Some(0), "restore must leave watermark at 0");
            for (i, (layer, side)) in (0..spec.n_layer)
                .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
                .enumerate()
            {
                let after = format!("{:?}", m.stored_rows(id, layer, side).unwrap());
                prop_assert!(
                    after == before[i],
                    "stream ({layer}, {side:?}) diverges after tier round-trip"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn restore_rejects_corrupt_payload() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 2);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(21);
        append_n(&mut m, id, 9, &mut rng);
        let mut parked = m.extract_sequence_bytes(id).unwrap();
        parked.payload.pop(); // wrong total length
        assert!(m.restore_sequence_bytes(id, &parked).is_err());
        parked.payload.push(0);
        parked.len = 8; // wrong row count
        assert!(m.restore_sequence_bytes(id, &parked).is_err());
        parked.len = 9;
        m.restore_sequence_bytes(id, &parked).unwrap();
        assert_eq!(m.seq_len(id), Some(9));
    }

    #[test]
    fn demotion_re_encodes_to_int8_and_survives_a_tier_round_trip() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 2); // f32 streams
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(41);
        append_n(&mut m, id, 20, &mut rng);
        let before_bytes = m.seq_stored_bytes(id);
        let before_rows = match m.stored_rows(id, 0, Side::K).unwrap() {
            StoredRows::Latent(rows) => rows,
            other => panic!("{other:?}"),
        };

        let freed = m.demote_sequence(id).unwrap();
        assert!(freed > 0, "f32 -> int8 must free bytes");
        assert!(m.seq_demoted(id));
        assert_eq!(m.seq_stored_bytes(id), before_bytes - freed);
        assert_eq!(m.decoded_upto(id), Some(0), "stale scratch must not survive");
        // lossy but close: one quantization of the original rows
        match m.stored_rows(id, 0, Side::K).unwrap() {
            StoredRows::Latent(rows) => {
                assert_eq!(rows.len(), before_rows.len());
                for (a, b) in rows.iter().zip(&before_rows) {
                    assert!((a - b).abs() < 0.05, "{a} vs {b}");
                }
            }
            other => panic!("{other:?}"),
        }
        // idempotent
        assert_eq!(m.demote_sequence(id).unwrap(), 0);
        // appends stay on the int8 rung
        append_n(&mut m, id, 1, &mut rng);
        let streams: Vec<String> = (0..spec.n_layer)
            .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
            .map(|(l, s)| format!("{:?}", m.stored_rows(id, l, s).unwrap()))
            .collect();
        // the parked flag drives an int8 wire layout on restore
        let parked = m.extract_sequence_bytes(id).unwrap();
        assert!(parked.demoted);
        m.restore_sequence_bytes(id, &parked).unwrap();
        assert!(m.seq_demoted(id));
        for (i, (l, s)) in (0..spec.n_layer)
            .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
            .enumerate()
        {
            assert_eq!(
                format!("{:?}", m.stored_rows(id, l, s).unwrap()),
                streams[i],
                "stream ({l}, {s:?}) diverges after a demoted tier round-trip"
            );
        }
        m.free_sequence(id);
        assert_eq!(m.pool_stats().live_bytes, 0);
    }

    /// Prefill-lane-shaped buffers ([L, n, *]) for `n` prompt rows.
    fn lane_bufs(rng: &mut Rng, spec: &ModelSpec, n: usize) -> [Vec<f32>; 4] {
        [
            rand_rows(rng, spec.n_layer * n * spec.ae_latent),
            rand_rows(rng, spec.n_layer * n * spec.ae_latent),
            rand_rows(rng, spec.n_layer * n * spec.kv_dim()),
            rand_rows(rng, spec.n_layer * n * spec.kv_dim()),
        ]
    }

    #[test]
    fn shared_ingest_matches_private_ingest_bitwise() {
        // the core sharing contract: a sequence admitted through the
        // shared-prefix trie reads every stream bitwise-identical to a
        // plain append of the same lane, across random plans
        check(20, |rng| {
            let spec = tiny_spec();
            let plan = random_plan(rng, &spec);
            let mut shared = CacheManager::new(CacheConfig::new(spec.clone(), plan.clone()));
            let mut plain = CacheManager::new(CacheConfig::new(spec.clone(), plan));
            let plen = rng.range(1, spec.max_seq);
            let toks: Vec<u8> = (0..plen).map(|_| rng.below(256) as u8).collect();
            let [kl, vl, kr, vr] = lane_bufs(rng, &spec, plen);
            let sid = shared.create_sequence();
            let si = shared
                .ingest_prompt_shared(sid, &toks, plen, &kl, &vl, &kr, &vr)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                si.prefix_rows == (plen / shared.cfg.block_size) * shared.cfg.block_size,
                "prefix must cover exactly the full leading chunks"
            );
            prop_assert!(si.reused_rows == 0, "first ingest has nothing to reuse");
            let pid = plain.create_sequence();
            plain
                .append_rows(pid, plen, plen, &kl, &vl, &kr, &vr)
                .map_err(|e| e.to_string())?;
            prop_assert!(shared.seq_len(sid) == plain.seq_len(pid));
            for layer in 0..spec.n_layer {
                for side in [Side::K, Side::V] {
                    let a = format!("{:?}", shared.stored_rows(sid, layer, side));
                    let b = format!("{:?}", plain.stored_rows(pid, layer, side));
                    prop_assert!(a == b, "shared stream ({layer}, {side:?}) diverges");
                }
            }
            // a second sharer of the same prompt stores zero new prefix
            // bytes: only its (identical) tail is private
            let live_before = shared.pool_stats().live_bytes;
            let sid2 = shared.create_sequence();
            let si2 = shared
                .ingest_prompt_shared(sid2, &toks, plen, &kl, &vl, &kr, &vr)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                si2.reused_rows == si.prefix_rows,
                "second sharer must reuse every chunk"
            );
            prop_assert!(
                shared.pool_stats().live_bytes - live_before
                    == shared.seq_stored_bytes(sid2),
                "second sharer may only add its private tail bytes"
            );
            prop_assert!(
                shared.seq_shared_bytes(sid2) == shared.seq_shared_bytes(sid),
                "sharers read the same chain"
            );
            // releasing one sharer keeps the chain; releasing both frees
            // everything (no leak, no double-free)
            shared.free_sequence(sid);
            shared.prefix_integrity(&[]).map_err(|e| e.to_string())?;
            if si.prefix_rows > 0 {
                prop_assert!(shared.prefix_stats().nodes_live > 0, "chain must survive a sharer");
            }
            shared.free_sequence(sid2);
            shared.prefix_integrity(&[]).map_err(|e| e.to_string())?;
            prop_assert!(shared.prefix_stats().nodes_live == 0, "last release frees the chain");
            prop_assert!(shared.pool_stats().live_bytes == 0, "no bytes may leak");
            Ok(())
        });
    }

    #[test]
    fn parked_sharer_spills_suffix_only_and_roundtrips() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 2);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut rng = Rng::new(31);
        let plen = m.cfg.block_size * 2 + 5; // two shared chunks + tail
        let toks: Vec<u8> = (0..plen).map(|_| rng.below(256) as u8).collect();
        let [kl, vl, kr, vr] = lane_bufs(&mut rng, &spec, plen);
        let a = m.create_sequence();
        m.ingest_prompt_shared(a, &toks, plen, &kl, &vl, &kr, &vr).unwrap();
        let b = m.create_sequence();
        m.ingest_prompt_shared(b, &toks, plen, &kl, &vl, &kr, &vr).unwrap();
        let before: Vec<String> = (0..spec.n_layer)
            .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
            .map(|(l, s)| format!("{:?}", m.stored_rows(a, l, s).unwrap()))
            .collect();
        let shared_bytes = m.prefix_stats().shared_bytes;

        let parked = m.extract_sequence_bytes(a).unwrap();
        assert_eq!(parked.prefix_rows, m.cfg.block_size * 2);
        assert_eq!(parked.len, plen);
        // only suffix bytes travel: strictly less than an unshared park
        let own_rows = plen - parked.prefix_rows;
        let expected: usize = (0..spec.n_layer)
            .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
            .map(|(l, s)| {
                let kind = m.cfg.store_kind(l, s);
                let epr = kind.elements(&spec);
                if epr == 0 { 0 } else { own_rows * m.cfg.format_for(&kind).row_bytes(epr) }
            })
            .sum();
        assert_eq!(parked.payload.len(), expected, "only the suffix spills");
        // the shared chain stayed resident for sharer b
        assert_eq!(m.prefix_stats().shared_bytes, shared_bytes);
        assert!(m.stored_rows(b, 0, Side::K).is_ok(), "sharer b unaffected");
        m.prefix_integrity(&[]).unwrap();

        m.restore_sequence_bytes(a, &parked).unwrap();
        for (i, (l, s)) in (0..spec.n_layer)
            .flat_map(|l| [Side::K, Side::V].map(|s| (l, s)))
            .enumerate()
        {
            assert_eq!(
                format!("{:?}", m.stored_rows(a, l, s).unwrap()),
                before[i],
                "stream ({l}, {s:?}) diverges after a shared tier round-trip"
            );
        }
        // retiring a *parked* sharer releases its chain reference too
        let parked_b = m.extract_sequence_bytes(b).unwrap();
        assert_eq!(parked_b.prefix_rows, m.cfg.block_size * 2);
        m.free_sequence(b);
        m.free_sequence(a);
        m.prefix_integrity(&[]).unwrap();
        assert_eq!(m.prefix_stats().nodes_live, 0);
        assert_eq!(m.pool_stats().live_bytes, 0);
    }

    #[test]
    fn prefix_pins_survive_sequence_churn() {
        let spec = tiny_spec();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let mut rng = Rng::new(33);
        let plen = m.cfg.block_size; // exactly one shared chunk, no tail
        let toks: Vec<u8> = (0..plen).map(|_| rng.below(256) as u8).collect();
        let [kl, vl, kr, vr] = lane_bufs(&mut rng, &spec, plen);
        let id = m.create_sequence();
        let si = m.ingest_prompt_shared(id, &toks, plen, &kl, &vl, &kr, &vr).unwrap();
        let leaf = si.leaf.expect("one full chunk");
        m.prefix_ref(leaf).unwrap(); // template-style pin
        m.free_sequence(id);
        m.prefix_integrity(&[leaf]).unwrap();
        assert_eq!(m.prefix_stats().nodes_live, 1, "pin keeps the chain warm");
        // a later admission re-attaches with zero new prefix bytes
        let id2 = m.create_sequence();
        let si2 = m.ingest_prompt_shared(id2, &toks, plen, &kl, &vl, &kr, &vr).unwrap();
        assert_eq!(si2.reused_rows, plen);
        m.free_sequence(id2);
        m.prefix_unref(leaf);
        m.prefix_integrity(&[]).unwrap();
        assert_eq!(m.prefix_stats().nodes_live, 0);
        assert_eq!(m.pool_stats().live_bytes, 0);
    }

    #[test]
    fn int8_latent_rows_are_close() {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 4).with_quant();
        let mut m = CacheManager::new(CacheConfig::new(spec.clone(), plan));
        let id = m.create_sequence();
        let mut rng = Rng::new(8);
        let kl = rand_rows(&mut rng, spec.n_layer * spec.ae_latent);
        let kr = vec![0.0; spec.n_layer * spec.kv_dim()];
        m.append_token(id, &kl, &kl, &kr, &kr).unwrap();
        if let StoredRows::Latent(rows) = m.stored_rows(id, 0, Side::K).unwrap() {
            for (a, b) in rows.iter().zip(&kl[..spec.ae_latent]) {
                assert!((a - b).abs() < 0.05, "{a} vs {b}");
            }
        } else {
            panic!("expected latent");
        }
    }
}
