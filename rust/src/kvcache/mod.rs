//! Paged, compressed KV cache (the KV-CAR storage engine): pooled block
//! storage with per-stream codecs (`block`, `allocator`), the
//! per-sequence manager and zero-copy retrieval views (`manager`), the
//! cross-request shared-prefix trie whose refcounted chunk blocks turn
//! prefix cache bytes from O(requests) into O(distinct prompts)
//! (`prefix`), and the host-offload tier that moves encoded bytes
//! off-device (`tier`).

pub mod allocator;
pub mod block;
pub mod manager;
pub mod prefix;
pub mod tier;

pub use block::{Format, RowsView};
pub use manager::{
    CacheConfig, CacheManager, ParkedBytes, SharedIngest, Side, StoreKind, StoredRows, StreamRows,
    StreamView,
};
pub use prefix::{PrefixIndex, PrefixStats};
