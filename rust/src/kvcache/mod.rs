//! Paged, compressed KV cache (the KV-CAR storage engine).

pub mod allocator;
pub mod block;
pub mod manager;
pub mod tier;

pub use block::{Format, RowsView};
pub use manager::{CacheConfig, CacheManager, Side, StoreKind, StoredRows, StreamRows, StreamView};
