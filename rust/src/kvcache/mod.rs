//! Paged, compressed KV cache (the KV-CAR storage engine): pooled block
//! storage with per-stream codecs (`block`, `allocator`), the
//! per-sequence manager and zero-copy retrieval views (`manager`), the
//! cross-request shared-prefix trie whose refcounted chunk blocks turn
//! prefix cache bytes from O(requests) into O(distinct prompts)
//! (`prefix`), the host-offload tier that moves encoded bytes
//! off-device (`tier`), and the rsync-style delta-transfer protocol
//! cross-worker sequence migration ships payloads with (`delta`).

pub mod allocator;
pub mod block;
pub mod delta;
pub mod manager;
pub mod prefix;
pub mod tier;

pub use block::{Format, RowsView};
pub use delta::{BlockManifest, DeltaPayload, GroupSum};
pub use manager::{
    chunk_chain_id, CacheConfig, CacheManager, ParkedBytes, SharedIngest, Side, StoreKind,
    StoredRows, StreamRows, StreamView,
};
pub use prefix::{PrefixIndex, PrefixStats};
