//! Paged, compressed KV cache (the KV-CAR storage engine): pooled block
//! storage with per-stream codecs (`block`, `allocator`), the
//! per-sequence manager and zero-copy retrieval views (`manager`), and
//! the host-offload tier that moves encoded bytes off-device (`tier`).

pub mod allocator;
pub mod block;
pub mod manager;
pub mod tier;

pub use block::{Format, RowsView};
pub use manager::{
    CacheConfig, CacheManager, ParkedBytes, Side, StoreKind, StoredRows, StreamRows, StreamView,
};
