//! Rsync-style delta transfer over the [`ParkedBytes`] wire format —
//! the byte-level substrate of cross-worker sequence migration
//! (DESIGN.md §10).
//!
//! A sequence's KV grows append-only in immutable encoded blocks, so
//! two extractions of the same sequence differ only in the rows
//! appended between them.  This module exploits that: the payload is
//! cut into **row groups** of `block_size` rows (aligned with the
//! storage blocks — `prefix_rows` is block-aligned and own blocks fill
//! from row zero, so group boundaries never straddle a block), each
//! group is checksummed, and a transfer ships only the groups whose
//! checksum the receiver cannot reproduce from a retained basis
//! payload.  Every full group of an earlier extraction is byte-stable
//! across re-extraction, so a re-migration ships O(new rows), not O(S).
//!
//! A group covers the *same* row range of every stored stream: group
//! `g` of a payload with `own = len - prefix_rows` suffix rows is the
//! concatenation, in wire order, of each stored stream's encoded bytes
//! for own rows `[g·bs, min((g+1)·bs, own))`.  Gathering across
//! streams (rather than per-stream groups) keeps the manifest small
//! and makes "rows appended since the basis" the only source of group
//! churn.
//!
//! Verification mirrors the host tier's CRC contract
//! ([`crate::kvcache::tier`]): every shipped or basis-reused group is
//! re-checksummed against the sender's manifest during
//! [`assemble`], and a mismatch is reported with the same
//! "checksum mismatch" wording the tier uses, so the supervisor types
//! it as a corruption fault and quarantines the transfer instead of
//! retrying garbage.

use super::manager::{CacheConfig, ParkedBytes};
use super::tier::crc32;
use anyhow::{anyhow, Result};

/// Checksum of one row group of a [`ParkedBytes`] payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSum {
    /// suffix rows this group covers (the last group may be partial)
    pub rows: usize,
    /// payload bytes of the group, summed across stored streams
    pub bytes: usize,
    /// CRC32 over the group's gathered bytes
    pub crc: u32,
}

/// Per-row-group checksum manifest of one extracted payload — the
/// negotiation half of a delta transfer: the sender computes it from
/// the payload it just extracted, the receiver diffs it against the
/// manifest of its retained basis, and only the disagreeing groups
/// ship.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockManifest {
    /// total token rows the sequence covers (prefix + suffix)
    pub len: usize,
    /// leading rows resident in the shared prefix store (not in the
    /// payload; content-addressed chunks move them separately)
    pub prefix_rows: usize,
    /// payload encoded on the int8 demotion rung (changes every
    /// stream's row width, so a demotion forces a full re-ship)
    pub demoted: bool,
    /// block-aligned own-row spans regionally demoted to int8 (sorted,
    /// disjoint, absolute rows).  Changes only the affected groups' row
    /// widths, so unlike the whole-sequence `demoted` flag a regional
    /// demotion churns — and re-ships — only the groups it re-encoded;
    /// carried so the receiver derives the same per-block layout and
    /// the assembled [`ParkedBytes`] keeps the sender's flags.
    pub demoted_spans: Vec<(usize, usize)>,
    /// rows per group (the cache's `block_size`)
    pub group_rows: usize,
    /// per-group checksums, ascending over the own-suffix rows
    pub groups: Vec<GroupSum>,
    /// CRC32 over the whole payload (end-to-end check after assembly)
    pub payload_crc: u32,
}

impl BlockManifest {
    /// Total payload bytes the manifest describes (what a full,
    /// delta-free transfer would ship).
    pub fn full_bytes(&self) -> usize {
        self.groups.iter().map(|g| g.bytes).sum()
    }
}

/// The bytes one delta transfer actually ships: the groups the
/// receiver could not reproduce, each tagged with its index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeltaPayload {
    /// `(group index, gathered group bytes)` in ascending index order
    pub groups: Vec<(usize, Vec<u8>)>,
}

impl DeltaPayload {
    /// Bytes on the wire for this transfer (the delta-law numerator:
    /// compare against [`BlockManifest::full_bytes`]).
    pub fn shipped_bytes(&self) -> usize {
        self.groups.iter().map(|(_, b)| b.len()).sum()
    }
}

/// Per-stream, per-group byte extents of a payload in wire order —
/// `extents[stream][group] = (offset, bytes)` for every byte-bearing
/// stream — plus the total payload size.  Groups coincide with own
/// storage blocks, so the extents come straight from the per-block
/// format layout ([`CacheConfig::own_block_layout`]): under a uniform
/// plan every group of a stream has one width, and under mixed rungs
/// or regional demotion each group prices its own block's format.
fn group_extents(
    cfg: &CacheConfig,
    len: usize,
    prefix_rows: usize,
    demoted: bool,
    demoted_spans: &[(usize, usize)],
) -> (Vec<Vec<(usize, usize)>>, usize) {
    let bs = cfg.block_size;
    let own = len - prefix_rows;
    let mut extents = Vec::new();
    let mut off = 0usize;
    for (epr, fmts) in cfg.own_block_layout(len, prefix_rows, demoted, demoted_spans) {
        if epr == 0 {
            continue;
        }
        let mut stream = Vec::with_capacity(fmts.len());
        for (b, fmt) in fmts.iter().enumerate() {
            let rows = bs.min(own - b * bs);
            let nbytes = rows * fmt.row_bytes(epr);
            stream.push((off, nbytes));
            off += nbytes;
        }
        extents.push(stream);
    }
    (extents, off)
}

/// Gather group `g`'s bytes (the same own-block rows of every stored
/// stream, wire order) out of a stream-major payload.
fn gather_group(payload: &[u8], extents: &[Vec<(usize, usize)>], g: usize, out: &mut Vec<u8>) {
    out.clear();
    for stream in extents {
        let (off, nbytes) = stream[g];
        out.extend_from_slice(&payload[off..off + nbytes]);
    }
}

/// Compute the per-group checksum manifest of an extracted payload.
/// Fails if the payload's length disagrees with the wire layout the
/// config derives (a corrupted or mis-attributed payload must not
/// produce a plausible manifest).
pub fn manifest(cfg: &CacheConfig, parked: &ParkedBytes) -> Result<BlockManifest> {
    let bs = cfg.block_size;
    let own = parked.len - parked.prefix_rows;
    let (extents, total) = group_extents(
        cfg,
        parked.len,
        parked.prefix_rows,
        parked.demoted,
        &parked.demoted_spans,
    );
    anyhow::ensure!(
        parked.payload.len() == total,
        "payload is {} bytes, wire layout derives {total}",
        parked.payload.len()
    );
    let n_groups = own.div_ceil(bs);
    let mut groups = Vec::with_capacity(n_groups);
    let mut scratch = Vec::new();
    for g in 0..n_groups {
        let rows = bs.min(own - g * bs);
        gather_group(&parked.payload, &extents, g, &mut scratch);
        groups.push(GroupSum {
            rows,
            bytes: scratch.len(),
            crc: crc32(&scratch),
        });
    }
    Ok(BlockManifest {
        len: parked.len,
        prefix_rows: parked.prefix_rows,
        demoted: parked.demoted,
        demoted_spans: parked.demoted_spans.clone(),
        group_rows: bs,
        groups,
        payload_crc: crc32(&parked.payload),
    })
}

/// Indices of the groups the receiver must be sent: every group when
/// there is no usable basis (none retained, or the layout moved under
/// it — whole-sequence demotion re-encodes every stream, a prefix
/// change re-bases row numbering), otherwise exactly the groups whose
/// checksum the basis cannot reproduce.  Append-only growth means in
/// the common re-migration case this is the trailing partial group
/// plus anything appended after it; a *regional* demotion re-encodes
/// only its own blocks, so the per-group compare re-ships exactly the
/// churned groups rather than blanket-invalidating the basis.
pub fn diff(incoming: &BlockManifest, basis: Option<&BlockManifest>) -> Vec<usize> {
    let all = || (0..incoming.groups.len()).collect();
    let Some(basis) = basis else { return all() };
    if basis.demoted != incoming.demoted
        || basis.prefix_rows != incoming.prefix_rows
        || basis.group_rows != incoming.group_rows
    {
        return all();
    }
    incoming
        .groups
        .iter()
        .enumerate()
        .filter(|&(g, sum)| basis.groups.get(g) != Some(sum))
        .map(|(g, _)| g)
        .collect()
}

/// Gather the requested groups out of a payload — the sender half of a
/// delta transfer.
pub fn extract(cfg: &CacheConfig, parked: &ParkedBytes, wanted: &[usize]) -> Result<DeltaPayload> {
    let bs = cfg.block_size;
    let own = parked.len - parked.prefix_rows;
    let (extents, total) = group_extents(
        cfg,
        parked.len,
        parked.prefix_rows,
        parked.demoted,
        &parked.demoted_spans,
    );
    anyhow::ensure!(
        parked.payload.len() == total,
        "payload is {} bytes, wire layout derives {total}",
        parked.payload.len()
    );
    let n_groups = own.div_ceil(bs);
    let mut groups = Vec::with_capacity(wanted.len());
    for &g in wanted {
        anyhow::ensure!(g < n_groups, "group {g} out of range ({n_groups} groups)");
        let mut bytes = Vec::new();
        gather_group(&parked.payload, &extents, g, &mut bytes);
        groups.push((g, bytes));
    }
    Ok(DeltaPayload { groups })
}

/// Rebuild the full payload the sender's manifest describes from the
/// shipped delta plus the receiver's retained basis — the receiver
/// half of a delta transfer.  Every group is CRC-verified against the
/// manifest (shipped and basis-reused alike), and the assembled whole
/// is verified end-to-end, so a corrupted transfer or a stale basis
/// surfaces as a typed "checksum mismatch" error instead of restoring
/// garbage into the destination cache.
pub fn assemble(
    cfg: &CacheConfig,
    incoming: &BlockManifest,
    basis: Option<&ParkedBytes>,
    delta: &DeltaPayload,
) -> Result<ParkedBytes> {
    let bs = incoming.group_rows;
    anyhow::ensure!(
        bs == cfg.block_size,
        "manifest groups span {bs} rows, cache blocks span {}",
        cfg.block_size
    );
    let own = incoming.len - incoming.prefix_rows;
    let (extents, total) = group_extents(
        cfg,
        incoming.len,
        incoming.prefix_rows,
        incoming.demoted,
        &incoming.demoted_spans,
    );
    anyhow::ensure!(
        own.div_ceil(bs) == incoming.groups.len(),
        "manifest has {} groups, layout derives {}",
        incoming.groups.len(),
        own.div_ceil(bs)
    );
    // the basis groups we may reuse, gathered lazily below (laid out by
    // the basis payload's *own* flags — its spans may differ from the
    // incoming payload's)
    let basis_extents = basis.map(|b| {
        let basis_own = b.len - b.prefix_rows;
        let (e, t) = group_extents(cfg, b.len, b.prefix_rows, b.demoted, &b.demoted_spans);
        (e, t, basis_own)
    });
    let mut payload = vec![0u8; total];
    let shipped: std::collections::HashMap<usize, &Vec<u8>> =
        delta.groups.iter().map(|(g, b)| (*g, b)).collect();
    let mut used = 0usize;
    let mut scratch = Vec::new();
    for (g, sum) in incoming.groups.iter().enumerate() {
        let group_bytes: &[u8] = match shipped.get(&g) {
            Some(bytes) => {
                used += 1;
                bytes
            }
            None => {
                // not shipped: the sender expects us to reproduce it
                // from the retained basis
                let Some(basis) = basis else {
                    anyhow::bail!("delta omits group {g} but no basis payload is retained");
                };
                let Some((bextents, btotal, basis_own)) = basis_extents.as_ref() else {
                    unreachable!("basis_extents mirrors basis")
                };
                anyhow::ensure!(
                    basis.payload.len() == *btotal,
                    "basis payload is {} bytes, wire layout derives {btotal}",
                    basis.payload.len()
                );
                anyhow::ensure!(
                    basis.demoted == incoming.demoted
                        && basis.prefix_rows == incoming.prefix_rows
                        && g * bs + sum.rows <= *basis_own,
                    "delta omits group {g} but the basis does not cover it"
                );
                gather_group(&basis.payload, bextents, g, &mut scratch);
                &scratch
            }
        };
        anyhow::ensure!(
            group_bytes.len() == sum.bytes,
            "group {g} is {} bytes, manifest says {}",
            group_bytes.len(),
            sum.bytes
        );
        let got = crc32(group_bytes);
        anyhow::ensure!(
            got == sum.crc,
            "checksum mismatch assembling migration group {g}: \
             {} bytes corrupted in transfer (crc {got:#010x} != {:#010x})",
            sum.bytes,
            sum.crc
        );
        // scatter the gathered group back into stream-major layout
        let mut read = 0usize;
        for stream in &extents {
            let (dst, n) = stream[g];
            payload[dst..dst + n].copy_from_slice(&group_bytes[read..read + n]);
            read += n;
        }
    }
    anyhow::ensure!(
        used == delta.groups.len(),
        "delta ships groups the manifest does not describe"
    );
    let got = crc32(&payload);
    anyhow::ensure!(
        got == incoming.payload_crc,
        "checksum mismatch assembling migrated payload: \
         {} bytes (crc {got:#010x} != {:#010x})",
        payload.len(),
        incoming.payload_crc
    );
    Ok(ParkedBytes {
        len: incoming.len,
        prefix_rows: incoming.prefix_rows,
        demoted: incoming.demoted,
        demoted_spans: incoming.demoted_spans.clone(),
        payload,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::CacheManager;
    use crate::model::memory::CompressionPlan;
    use crate::model::{Arch, ModelSpec};
    use crate::util::rng::Rng;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            name: "test".into(),
            arch: Arch::Gpt2,
            vocab: 256,
            n_layer: 3,
            d_model: 32,
            n_head: 4,
            n_kv_head: 4,
            d_head: 8,
            ffn_dim: 64,
            max_seq: 96,
            ae_hidden: 24,
            ae_latent: 12,
            bytes_per_el: 4,
        }
    }

    fn manager() -> CacheManager {
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 1);
        CacheManager::new(CacheConfig::new(spec, plan))
    }

    fn append_n(m: &mut CacheManager, id: u64, n: usize, rng: &mut Rng) {
        let spec = m.cfg.spec.clone();
        for _ in 0..n {
            let kl: Vec<f32> = (0..spec.n_layer * spec.ae_latent)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let vl: Vec<f32> = (0..spec.n_layer * spec.ae_latent)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let kr: Vec<f32> = (0..spec.n_layer * spec.kv_dim())
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let vr: Vec<f32> = (0..spec.n_layer * spec.kv_dim())
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            m.append_token(id, &kl, &vl, &kr, &vr).unwrap();
        }
    }

    #[test]
    fn manifest_groups_align_with_storage_blocks() {
        let mut m = manager();
        let mut rng = Rng::new(7);
        let id = m.create_sequence();
        append_n(&mut m, id, 40, &mut rng); // 16 + 16 + 8 rows
        let parked = m.extract_sequence_bytes(id).unwrap();
        let man = manifest(&m.cfg, &parked).unwrap();
        assert_eq!(man.groups.len(), 3);
        assert_eq!(
            man.groups.iter().map(|g| g.rows).collect::<Vec<_>>(),
            vec![16, 16, 8]
        );
        assert_eq!(man.full_bytes(), parked.payload.len());
        assert_eq!(man.payload_crc, crc32(&parked.payload));
    }

    #[test]
    fn full_transfer_roundtrips_bitwise() {
        let mut m = manager();
        let mut rng = Rng::new(11);
        let id = m.create_sequence();
        append_n(&mut m, id, 35, &mut rng);
        let parked = m.extract_sequence_bytes(id).unwrap();
        let man = manifest(&m.cfg, &parked).unwrap();
        let wanted = diff(&man, None);
        assert_eq!(wanted, vec![0, 1, 2]);
        let delta = extract(&m.cfg, &parked, &wanted).unwrap();
        assert_eq!(delta.shipped_bytes(), man.full_bytes());
        let back = assemble(&m.cfg, &man, None, &delta).unwrap();
        assert_eq!(back, parked, "full transfer must be bit-identical");
    }

    #[test]
    fn delta_law_reships_only_appended_groups() {
        let mut m = manager();
        let mut rng = Rng::new(23);
        let id = m.create_sequence();
        append_n(&mut m, id, 40, &mut rng);
        // first transfer: the receiver retains this payload as basis
        let basis = m.extract_sequence_bytes(id).unwrap();
        let basis_man = manifest(&m.cfg, &basis).unwrap();
        m.restore_sequence_bytes(id, &basis).unwrap();
        // sequence grows append-only, then re-migrates
        append_n(&mut m, id, 16, &mut rng);
        let parked = m.extract_sequence_bytes(id).unwrap();
        let man = manifest(&m.cfg, &parked).unwrap();
        let wanted = diff(&man, Some(&basis_man));
        // full groups 0 and 1 are byte-stable; the old partial group 2
        // grew and group 3 is new
        assert_eq!(wanted, vec![2, 3]);
        let delta = extract(&m.cfg, &parked, &wanted).unwrap();
        assert!(
            delta.shipped_bytes() < man.full_bytes(),
            "delta law: {} shipped vs {} full",
            delta.shipped_bytes(),
            man.full_bytes()
        );
        let back = assemble(&m.cfg, &man, Some(&basis), &delta).unwrap();
        assert_eq!(back, parked, "delta assembly must be bit-identical");
    }

    #[test]
    fn corrupted_group_trips_checksum_mismatch() {
        let mut m = manager();
        let mut rng = Rng::new(41);
        let id = m.create_sequence();
        append_n(&mut m, id, 20, &mut rng);
        let parked = m.extract_sequence_bytes(id).unwrap();
        let man = manifest(&m.cfg, &parked).unwrap();
        let mut delta = extract(&m.cfg, &parked, &diff(&man, None)).unwrap();
        // single in-flight bit flip in the second group
        let bytes = &mut delta.groups[1].1;
        let at = bytes.len() / 2;
        bytes[at] ^= 1;
        let err = assemble(&m.cfg, &man, None, &delta).unwrap_err();
        assert!(
            err.to_string().contains("checksum mismatch"),
            "corruption must surface as a checksum mismatch: {err}"
        );
    }

    #[test]
    fn demotion_invalidates_the_basis_entirely() {
        let mut m = manager();
        let mut rng = Rng::new(53);
        let id = m.create_sequence();
        append_n(&mut m, id, 40, &mut rng);
        let basis = m.extract_sequence_bytes(id).unwrap();
        let basis_man = manifest(&m.cfg, &basis).unwrap();
        m.restore_sequence_bytes(id, &basis).unwrap();
        m.demote_sequence(id).unwrap();
        let parked = m.extract_sequence_bytes(id).unwrap();
        let man = manifest(&m.cfg, &parked).unwrap();
        // every stream re-encoded: the whole payload must re-ship
        assert_eq!(diff(&man, Some(&basis_man)), vec![0, 1, 2]);
        let delta = extract(&m.cfg, &parked, &diff(&man, Some(&basis_man))).unwrap();
        let back = assemble(&m.cfg, &man, None, &delta).unwrap();
        assert_eq!(back, parked);
    }

    #[test]
    fn regional_demotion_reships_only_churned_groups() {
        let mut m = manager();
        let mut rng = Rng::new(67);
        let id = m.create_sequence();
        append_n(&mut m, id, 40, &mut rng);
        let basis = m.extract_sequence_bytes(id).unwrap();
        let basis_man = manifest(&m.cfg, &basis).unwrap();
        m.restore_sequence_bytes(id, &basis).unwrap();
        // demote only the first block's rows — unlike a whole-sequence
        // demotion this must churn exactly one group
        let freed = m.demote_region(id, 0, 16).unwrap();
        assert!(freed > 0, "re-encoding f32 blocks to int8 frees bytes");
        let parked = m.extract_sequence_bytes(id).unwrap();
        assert_eq!(parked.demoted_spans, vec![(0, 16)]);
        let man = manifest(&m.cfg, &parked).unwrap();
        assert_eq!(
            diff(&man, Some(&basis_man)),
            vec![0],
            "only the demoted region's group re-ships"
        );
        let delta = extract(&m.cfg, &parked, &[0]).unwrap();
        assert!(delta.shipped_bytes() < man.full_bytes());
        let back = assemble(&m.cfg, &man, Some(&basis), &delta).unwrap();
        assert_eq!(back, parked, "regional delta assembly must be bit-identical");
    }

    #[test]
    fn mixed_rung_payloads_roundtrip_through_delta() {
        use crate::compress::strategy::{RegionSpec, Rung};
        let spec = tiny_spec();
        let plan = CompressionPlan::ae_first_layers(&spec, 1);
        let mut cfg = CacheConfig::new(spec, plan);
        cfg.regions = vec![
            RegionSpec {
                start: 0,
                end: Some(16),
                rung: Rung::RawF32,
            },
            RegionSpec {
                start: 16,
                end: Some(32),
                rung: Rung::Int8,
            },
            RegionSpec {
                start: 32,
                end: None,
                rung: Rung::RawF16,
            },
        ];
        let mut m = CacheManager::new(cfg);
        let mut rng = Rng::new(71);
        let id = m.create_sequence();
        append_n(&mut m, id, 40, &mut rng);
        // first transfer of the heterogeneous payload: bit-faithful
        let basis = m.extract_sequence_bytes(id).unwrap();
        let basis_man = manifest(&m.cfg, &basis).unwrap();
        let full = extract(&m.cfg, &basis, &diff(&basis_man, None)).unwrap();
        let back = assemble(&m.cfg, &basis_man, None, &full).unwrap();
        assert_eq!(back, basis, "mixed-rung full transfer must be bit-identical");
        // grow into the f16 tail region, then re-migrate: only the
        // churned trailing groups ship, across a format boundary
        m.restore_sequence_bytes(id, &basis).unwrap();
        append_n(&mut m, id, 16, &mut rng);
        let parked = m.extract_sequence_bytes(id).unwrap();
        let man = manifest(&m.cfg, &parked).unwrap();
        let wanted = diff(&man, Some(&basis_man));
        assert_eq!(wanted, vec![2, 3]);
        let delta = extract(&m.cfg, &parked, &wanted).unwrap();
        let back = assemble(&m.cfg, &man, Some(&basis), &delta).unwrap();
        assert_eq!(back, parked, "mixed-rung delta assembly must be bit-identical");
    }

    #[test]
    fn missing_basis_group_is_rejected() {
        let mut m = manager();
        let mut rng = Rng::new(61);
        let id = m.create_sequence();
        append_n(&mut m, id, 20, &mut rng);
        let parked = m.extract_sequence_bytes(id).unwrap();
        let man = manifest(&m.cfg, &parked).unwrap();
        // ship only group 1 with no basis: group 0 is unreconstructible
        let delta = extract(&m.cfg, &parked, &[1]).unwrap();
        let err = assemble(&m.cfg, &man, None, &delta).unwrap_err();
        assert!(err.to_string().contains("no basis"), "{err}");
    }
}
