//! Cross-request shared-prefix index: a refcounted trie of immutable,
//! encoded KV block chunks (the storage half of KV-CAR's reuse pillar
//! applied *across* requests — DESIGN.md §6).
//!
//! Production traffic shares system prompts and few-shot templates, so
//! the prefill KV rows of those shared prefixes are byte-identical
//! across requests (a causal transformer's row `t` depends only on
//! tokens `[0, t]` — the same per-position purity the `{m}_prefill_b`
//! lane contract rests on).  Storing them once turns prefix cache bytes
//! from O(requests) into O(distinct prompts).
//!
//! Structure: a trie keyed by `block_size`-token chunks of the clamped
//! prompt.  Each node owns one **full, immutable** [`Block`] per stored
//! (layer, K|V) stream — encoded exactly as a private append would have
//! encoded the same rows, which is what makes a shared read bitwise
//! equal to an unshared one.  A sequence references the chain root→leaf
//! covering its block-aligned prefix; its own blocks hold only the
//! suffix.  Two reference kinds keep a chain alive:
//!
//! * **`seq_refs`** — live (or parked) sequences whose prefix path
//!   includes the node; bumped by `CacheManager::attach_prefix`,
//!   dropped by `free_sequence`.  A parked sequence keeps its refs —
//!   its suffix bytes move to the host tier, the shared prefix stays
//!   device-resident for the other sharers.
//! * **`pins`** — admission-template holds (`CacheManager::prefix_ref`
//!   / `prefix_unref`): the coordinator's prompt-template cache pins
//!   the chains it can re-admit from with zero launches.
//!
//! A node is freed (blocks recycled to the pool) exactly when both
//! counts reach zero and no child survives — checked leaf-upward on
//! every release, so interior nodes outlive their referenced
//! descendants and a double-release is structurally impossible
//! (`integrity` re-derives every count for the property tests).

use super::allocator::BlockPool;
use super::block::Block;
use super::manager::Side;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Accounting for one [`PrefixIndex`]: trie size, hit/miss counters,
/// and the bytes the shared store holds exactly once.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixStats {
    /// trie nodes currently alive (each holds one block per stored stream)
    pub nodes_live: usize,
    /// chunk lookups that found an existing node (bytes not re-stored)
    pub chunk_hits: u64,
    /// chunk lookups that created a new node (bytes stored once)
    pub chunk_misses: u64,
    /// token rows attached from already-stored chunks, summed across
    /// admissions — the byte-dedup counterpart of launch savings
    pub reused_rows: u64,
    /// encoded block bytes held by live nodes (each counted once, no
    /// matter how many sequences share it)
    pub shared_bytes: usize,
}

struct Node {
    parent: Option<u32>,
    /// this node's chunk key inside its parent's (or the root) map
    key: Vec<u8>,
    children: HashMap<Vec<u8>, u32>,
    /// chunks on the path root..=self (rows = depth * block_size)
    depth: usize,
    /// sequences whose prefix path includes this node
    seq_refs: usize,
    /// external pins (admission-template cache) keeping the chain warm
    pins: usize,
    /// one full encoded block per (layer, K|V); `None` for
    /// fully-aliased streams, which store nothing anywhere
    blocks: Vec<[Option<Block>; 2]>,
    /// encoded bytes across this node's blocks
    bytes: usize,
}

/// The trie of refcounted shared-prefix chunks.  Owned by
/// `CacheManager`, which builds the blocks (it knows the store kinds
/// and formats) and allocates them from the same budgeted pool as
/// private sequence blocks.
#[derive(Default)]
pub struct PrefixIndex {
    nodes: Vec<Option<Node>>,
    free: Vec<u32>,
    roots: HashMap<Vec<u8>, u32>,
    /// hit/miss/byte accounting (see [`PrefixStats`])
    pub stats: PrefixStats,
}

impl PrefixIndex {
    /// Empty index.
    pub fn new() -> Self {
        Self::default()
    }

    fn node(&self, id: u32) -> Result<&Node> {
        self.nodes
            .get(id as usize)
            .and_then(Option::as_ref)
            .ok_or_else(|| anyhow!("unknown prefix node {id}"))
    }

    /// Child of `parent` (the root set when `None`) under `key`.
    pub fn child(&self, parent: Option<u32>, key: &[u8]) -> Option<u32> {
        match parent {
            None => self.roots.get(key).copied(),
            Some(p) => self
                .nodes
                .get(p as usize)
                .and_then(Option::as_ref)
                .and_then(|n| n.children.get(key).copied()),
        }
    }

    /// Chunks on the path root..=`node` (rows = `depth * block_size`).
    pub fn depth(&self, node: u32) -> Result<usize> {
        Ok(self.node(node)?.depth)
    }

    /// The `block_size` token bytes this node indexes under its parent
    /// — the identity content-addressed chunk export walks the chain
    /// with (`CacheManager::prefix_chain`).
    pub fn key(&self, node: u32) -> Result<&[u8]> {
        Ok(&self.node(node)?.key)
    }

    /// Encoded bytes the node's blocks hold.
    pub fn node_bytes(&self, node: u32) -> usize {
        self.node(node).map(|n| n.bytes).unwrap_or(0)
    }

    /// The stored block of one (layer, side) stream of a node (`None`
    /// for fully-aliased streams).
    pub fn block(&self, node: u32, layer: usize, side: Side) -> Option<&Block> {
        self.node(node)
            .ok()
            .and_then(|n| n.blocks.get(layer))
            .and_then(|pair| pair[side as usize].as_ref())
    }

    /// Insert a freshly-built chunk node under `parent` with zero
    /// references; the caller attaches or rolls back.  `blocks` is one
    /// `[K, V]` pair per layer, every stored stream a **full** block.
    pub fn add_child(
        &mut self,
        parent: Option<u32>,
        key: Vec<u8>,
        blocks: Vec<[Option<Block>; 2]>,
        bytes: usize,
    ) -> u32 {
        debug_assert!(self.child(parent, &key).is_none(), "duplicate prefix chunk");
        let depth = parent
            .and_then(|p| self.depth(p).ok())
            .map_or(1, |d| d + 1);
        let node = Node {
            parent,
            key: key.clone(),
            children: HashMap::new(),
            depth,
            seq_refs: 0,
            pins: 0,
            blocks,
            bytes,
        };
        let id = match self.free.pop() {
            Some(id) => {
                self.nodes[id as usize] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                (self.nodes.len() - 1) as u32
            }
        };
        match parent {
            None => self.roots.insert(key, id),
            Some(p) => self.nodes[p as usize]
                .as_mut()
                .expect("live parent")
                .children
                .insert(key, id),
        };
        self.stats.nodes_live += 1;
        self.stats.shared_bytes += bytes;
        id
    }

    /// The chain root→`leaf`.
    pub fn path(&self, leaf: u32) -> Result<Vec<u32>> {
        let mut path = Vec::new();
        let mut cur = Some(leaf);
        while let Some(id) = cur {
            path.push(id);
            cur = self.node(id)?.parent;
        }
        path.reverse();
        Ok(path)
    }

    fn bump_path(&mut self, leaf: u32, pin: bool) -> Result<Vec<u32>> {
        let path = self.path(leaf)?;
        for &id in &path {
            let n = self.nodes[id as usize].as_mut().expect("live path node");
            if pin {
                n.pins += 1;
            } else {
                n.seq_refs += 1;
            }
        }
        Ok(path)
    }

    /// Reference the chain root→`leaf` for a sequence; returns the path.
    pub fn attach(&mut self, leaf: u32) -> Result<Vec<u32>> {
        self.bump_path(leaf, false)
    }

    /// Pin the chain root→`leaf` (admission-template hold).
    pub fn pin(&mut self, leaf: u32) -> Result<()> {
        self.bump_path(leaf, true).map(|_| ())
    }

    fn drop_path(&mut self, leaf: u32, pin: bool, pool: &mut BlockPool) {
        let Ok(path) = self.path(leaf) else { return };
        for &id in &path {
            let n = self.nodes[id as usize].as_mut().expect("live path node");
            if pin {
                assert!(n.pins > 0, "prefix unpin without a matching pin");
                n.pins -= 1;
            } else {
                assert!(n.seq_refs > 0, "prefix detach without a matching attach");
                n.seq_refs -= 1;
            }
        }
        // sweep leaf-upward: free exactly the nodes nothing references
        // any more (a freed child may make its parent freeable)
        for &id in path.iter().rev() {
            let n = self.nodes[id as usize].as_ref().expect("live path node");
            if n.seq_refs + n.pins > 0 || !n.children.is_empty() {
                break;
            }
            self.remove_node(id, pool);
        }
    }

    /// Release a sequence's reference on the chain root→`leaf`,
    /// recycling any chunk nothing references any more.
    pub fn detach(&mut self, leaf: u32, pool: &mut BlockPool) {
        self.drop_path(leaf, false, pool);
    }

    /// Release a pin taken with [`PrefixIndex::pin`].
    pub fn unpin(&mut self, leaf: u32, pool: &mut BlockPool) {
        self.drop_path(leaf, true, pool);
    }

    /// Free one unreferenced, childless node (rollback of a chunk
    /// created by an admission that failed before attaching).
    pub fn remove_unreferenced(&mut self, id: u32, pool: &mut BlockPool) {
        let Ok(n) = self.node(id) else { return };
        assert!(
            n.seq_refs + n.pins == 0 && n.children.is_empty(),
            "prefix node {id} still referenced"
        );
        self.remove_node(id, pool);
    }

    fn remove_node(&mut self, id: u32, pool: &mut BlockPool) {
        let node = self.nodes[id as usize].take().expect("live node");
        match node.parent {
            None => {
                self.roots.remove(&node.key);
            }
            Some(p) => {
                if let Some(parent) = self.nodes[p as usize].as_mut() {
                    parent.children.remove(&node.key);
                }
            }
        }
        for pair in node.blocks {
            for b in pair.into_iter().flatten() {
                pool.free(b);
            }
        }
        self.stats.nodes_live -= 1;
        self.stats.shared_bytes -= node.bytes;
        self.free.push(id);
    }

    /// Re-derive every refcount from first principles and compare — the
    /// invariant the admit/park/resume/retire property test checks after
    /// every step.  `seq_paths` is each live sequence's prefix path,
    /// `pinned` each externally pinned leaf.
    pub fn integrity(&self, seq_paths: &[&[u32]], pinned: &[u32]) -> Result<(), String> {
        let mut want_seq: HashMap<u32, usize> = HashMap::new();
        let mut want_pin: HashMap<u32, usize> = HashMap::new();
        for path in seq_paths {
            for &id in *path {
                *want_seq.entry(id).or_default() += 1;
            }
        }
        for &leaf in pinned {
            let path = self.path(leaf).map_err(|e| e.to_string())?;
            for id in path {
                *want_pin.entry(id).or_default() += 1;
            }
        }
        let mut live = 0usize;
        let mut bytes = 0usize;
        for (id, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            let id = id as u32;
            live += 1;
            bytes += n.bytes;
            let ws = want_seq.get(&id).copied().unwrap_or(0);
            let wp = want_pin.get(&id).copied().unwrap_or(0);
            if n.seq_refs != ws {
                return Err(format!("node {id}: seq_refs {} != derived {ws}", n.seq_refs));
            }
            if n.pins != wp {
                return Err(format!("node {id}: pins {} != derived {wp}", n.pins));
            }
            if n.seq_refs + n.pins == 0 && n.children.is_empty() {
                return Err(format!("node {id}: unreferenced childless node leaked"));
            }
            // parent/child links are mutual
            match n.parent {
                None => {
                    if self.roots.get(&n.key) != Some(&id) {
                        return Err(format!("node {id}: root link broken"));
                    }
                }
                Some(p) => {
                    let parent = self
                        .nodes
                        .get(p as usize)
                        .and_then(Option::as_ref)
                        .ok_or_else(|| format!("node {id}: parent {p} is dead"))?;
                    if parent.children.get(&n.key) != Some(&id) {
                        return Err(format!("node {id}: parent {p} child link broken"));
                    }
                    if parent.depth + 1 != n.depth {
                        return Err(format!("node {id}: depth chain broken"));
                    }
                }
            }
        }
        if live != self.stats.nodes_live {
            return Err(format!(
                "nodes_live {} != counted {live}",
                self.stats.nodes_live
            ));
        }
        if bytes != self.stats.shared_bytes {
            return Err(format!(
                "shared_bytes {} != counted {bytes}",
                self.stats.shared_bytes
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::block::Format;

    fn one_block_chunk(pool: &mut BlockPool, rows: usize) -> (Vec<[Option<Block>; 2]>, usize) {
        let mut b = pool.alloc(Format::F32, 2, rows).unwrap();
        let flat: Vec<f32> = (0..rows * 2).map(|i| i as f32).collect();
        b.push_rows(&flat);
        let bytes = b.stored_bytes();
        (vec![[Some(b), None]], bytes)
    }

    #[test]
    fn trie_child_walk_finds_chains() {
        // the chunk walk ingest_prompt_shared performs: child() hits
        // along the stored chain, misses off it; path/depth consistent
        let mut pool = BlockPool::new();
        let mut ix = PrefixIndex::new();
        let (b1, n1) = one_block_chunk(&mut pool, 4);
        let a = ix.add_child(None, vec![1, 2, 3, 4], b1, n1);
        let (b2, n2) = one_block_chunk(&mut pool, 4);
        let b = ix.add_child(Some(a), vec![5, 6, 7, 8], b2, n2);
        assert_eq!(ix.child(None, &[1, 2, 3, 4]), Some(a));
        assert_eq!(ix.child(Some(a), &[5, 6, 7, 8]), Some(b));
        assert_eq!(ix.child(Some(a), &[9, 9, 9, 9]), None);
        assert_eq!(ix.child(None, &[9, 9, 9, 9]), None);
        assert_eq!(ix.path(b).unwrap(), vec![a, b]);
        assert_eq!(ix.depth(b).unwrap(), 2);
        assert_eq!(ix.depth(a).unwrap(), 1);
    }

    #[test]
    fn refcounts_free_leaf_up_and_keep_shared_interior() {
        let mut pool = BlockPool::new();
        let mut ix = PrefixIndex::new();
        let (b1, n1) = one_block_chunk(&mut pool, 4);
        let a = ix.add_child(None, vec![0; 4], b1, n1);
        let (b2, n2) = one_block_chunk(&mut pool, 4);
        let b = ix.add_child(Some(a), vec![1; 4], b2, n2);
        let (b3, n3) = one_block_chunk(&mut pool, 4);
        let c = ix.add_child(Some(a), vec![2; 4], b3, n3);
        // two sequences share a; one goes deeper to b, one to c
        ix.attach(b).unwrap();
        ix.attach(c).unwrap();
        let (path_b, path_c): (&[u32], &[u32]) = (&[a, b], &[a, c]);
        ix.integrity(&[path_b, path_c], &[]).unwrap();
        let live_before = pool.stats().live_bytes;
        // releasing the b-chain frees b only (a still shared via c)
        ix.detach(b, &mut pool);
        assert_eq!(ix.stats.nodes_live, 2);
        assert!(pool.stats().live_bytes < live_before);
        ix.integrity(&[path_c], &[]).unwrap();
        // releasing the last chain frees everything
        ix.detach(c, &mut pool);
        assert_eq!(ix.stats.nodes_live, 0);
        assert_eq!(ix.stats.shared_bytes, 0);
        assert_eq!(pool.stats().live_bytes, 0);
        ix.integrity(&[], &[]).unwrap();
    }

    #[test]
    fn pins_keep_chains_alive_without_sequences() {
        let mut pool = BlockPool::new();
        let mut ix = PrefixIndex::new();
        let (b1, n1) = one_block_chunk(&mut pool, 4);
        let a = ix.add_child(None, vec![0; 4], b1, n1);
        ix.pin(a).unwrap();
        ix.attach(a).unwrap();
        ix.detach(a, &mut pool); // sequence gone, template pin remains
        assert_eq!(ix.stats.nodes_live, 1);
        ix.integrity(&[], &[a]).unwrap();
        ix.unpin(a, &mut pool);
        assert_eq!(ix.stats.nodes_live, 0);
        assert_eq!(pool.stats().live_bytes, 0);
    }
}
