//! Evaluation harness: perplexity on the synthetic corpora and zero-shot
//! accuracy on the choice tasks — the measurements behind Tables II-V.
//!
//! Every configuration (baseline, AE-k-layers, head reuse, +int8) is the
//! *same* eval_loss artifact driven with different runtime masks, so
//! baseline and compressed numbers are perfectly comparable.

pub mod report;

use crate::compress::planner::RuntimeMasks;
use crate::data::batch::{choice_batches, lm_batch};
use crate::data::corpus::Corpus;
use crate::data::tasks::{generate, Task};
use crate::model::ModelSpec;
use crate::runtime::{Engine, Store, Tensor};
use anyhow::Result;

/// Rows per eval_loss artifact call.
pub const EVAL_BATCH: usize = 8;

fn apply_masks(store: &mut Store, spec: &ModelSpec, masks: &RuntimeMasks) {
    let (l, h) = (spec.n_layer, spec.n_kv_head);
    store.insert("compress", Tensor::f32(vec![l], masks.compress.clone()));
    store.insert("reuse_k", Tensor::f32(vec![l, h], masks.reuse_k.clone()));
    store.insert("reuse_v", Tensor::f32(vec![l, h], masks.reuse_v.clone()));
    store.insert("quant", Tensor::scalar_f32(masks.quant));
}

/// Perplexity over `batches` batches of the corpus under the given masks.
pub fn perplexity(
    engine: &mut Engine,
    store: &mut Store,
    spec: &ModelSpec,
    model: &str,
    corpus: &mut Corpus,
    batches: usize,
    masks: &RuntimeMasks,
) -> Result<f64> {
    let entry = format!("{model}_eval_loss");
    apply_masks(store, spec, masks);
    let s = spec.max_seq;
    let (mut nll_sum, mut tok_sum) = (0.0f64, 0.0f64);
    for _ in 0..batches {
        let tb = lm_batch(corpus, EVAL_BATCH, s);
        store.insert("tokens", Tensor::i32(vec![EVAL_BATCH, s], tb.tokens));
        store.insert("len_mask", Tensor::f32(vec![EVAL_BATCH, s], tb.mask));
        let out = engine.execute(&entry, store)?;
        nll_sum += out[0].1.as_f32()?.iter().map(|&x| x as f64).sum::<f64>();
        tok_sum += out[1].1.as_f32()?.iter().map(|&x| x as f64).sum::<f64>();
    }
    Ok((nll_sum / tok_sum.max(1.0)).exp())
}

#[derive(Debug, Clone)]
/// Accuracy of one zero-shot task run.
pub struct ZeroShotResult {
    /// task id
    pub task: &'static str,
    /// items scored
    pub items: usize,
    /// items the model preferred the right continuation on
    pub correct: usize,
}

impl ZeroShotResult {
    /// Fraction correct (0 when empty).
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.items.max(1) as f64
    }
}

/// Zero-shot accuracy: score both candidates of each item by summed NLL;
/// the lower-NLL candidate wins (exactly the real benchmarks' protocol).
pub fn zero_shot(
    engine: &mut Engine,
    store: &mut Store,
    spec: &ModelSpec,
    model: &str,
    task: Task,
    n_items: usize,
    seed: u64,
    masks: &RuntimeMasks,
) -> Result<ZeroShotResult> {
    let entry = format!("{model}_eval_loss");
    apply_masks(store, spec, masks);
    let items = generate(task, n_items, seed);
    let mut scores: Vec<(f64, f64)> = vec![(f64::NAN, f64::NAN); items.len()];
    for (tb, meta) in choice_batches(&items, EVAL_BATCH, spec.max_seq) {
        store.insert(
            "tokens",
            Tensor::i32(vec![EVAL_BATCH, spec.max_seq], tb.tokens.clone()),
        );
        store.insert(
            "len_mask",
            Tensor::f32(vec![EVAL_BATCH, spec.max_seq], tb.mask.clone()),
        );
        let out = engine.execute(&entry, store)?;
        let nll = out[0].1.as_f32()?;
        for (row, &(item, is_correct)) in meta.iter().enumerate() {
            if item == usize::MAX {
                continue;
            }
            if is_correct {
                scores[item].0 = nll[row] as f64;
            } else {
                scores[item].1 = nll[row] as f64;
            }
        }
    }
    let correct = scores
        .iter()
        .filter(|(c, w)| c.is_finite() && w.is_finite() && c < w)
        .count();
    Ok(ZeroShotResult {
        task: task.name(),
        items: items.len(),
        correct,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shot_result_math() {
        let r = ZeroShotResult {
            task: "piqa",
            items: 200,
            correct: 131,
        };
        assert!((r.accuracy() - 0.655).abs() < 1e-9);
    }
}
