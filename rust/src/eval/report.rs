//! Experiment report writer: renders result tables as aligned plain text
//! and GitHub markdown, and archives them as JSON — the format quoted in
//! EXPERIMENTS.md.  Keeping this in the library (rather than ad-hoc
//! println!s in examples) makes every repro table machine-diffable.

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
/// One table cell value.
pub enum Cell {
    /// verbatim text
    Str(String),
    /// number with a fixed decimal count
    Num(f64, usize), // value, decimals
    /// fraction rendered as a percentage
    Pct(f64),
}

impl Cell {
    /// Text cell.
    pub fn s(v: impl Into<String>) -> Cell {
        Cell::Str(v.into())
    }
    /// Number cell with `decimals` places.
    pub fn f(v: f64, decimals: usize) -> Cell {
        Cell::Num(v, decimals)
    }
    /// Percentage cell from a fraction.
    pub fn pct(v: f64) -> Cell {
        Cell::Pct(v)
    }

    fn render(&self) -> String {
        match self {
            Cell::Str(s) => s.clone(),
            Cell::Num(v, d) => format!("{v:.*}", d),
            Cell::Pct(v) => format!("{:.1}%", v * 100.0),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Cell::Str(s) => json::s(s),
            Cell::Num(v, _) => json::num(*v),
            Cell::Pct(v) => json::num(*v),
        }
    }
}

#[derive(Debug, Clone)]
/// A titled results table renderable as text/markdown/JSON.
pub struct Table {
    /// table heading
    pub title: String,
    /// column headers
    pub columns: Vec<String>,
    /// row-major cells
    pub rows: Vec<Vec<Cell>>,
}

impl Table {
    /// Empty table with headers.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (chainable).
    pub fn row(&mut self, cells: Vec<Cell>) -> &mut Self {
        assert_eq!(cells.len(), self.columns.len(), "{}", self.title);
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.columns.iter().map(String::len).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.render().len());
            }
        }
        w
    }

    /// Fixed-width text rendering.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = format!("{}\n", self.title);
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
            .collect();
        out.push_str(&format!("  {}\n", header.join("  ")));
        for r in &self.rows {
            let cells: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c.render(), width = w[i]))
                .collect();
            out.push_str(&format!("  {}\n", cells.join("  ")));
        }
        out
    }

    /// Markdown table rendering.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.columns.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.columns.len())
        ));
        for r in &self.rows {
            let cells: Vec<String> = r.iter().map(Cell::render).collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        out
    }

    /// JSON rendering (EXPERIMENTS.md machine row).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("title", json::s(&self.title)),
            (
                "columns",
                json::arr(self.columns.iter().map(|c| json::s(c))),
            ),
            (
                "rows",
                json::arr(
                    self.rows
                        .iter()
                        .map(|r| json::arr(r.iter().map(Cell::to_json))),
                ),
            ),
        ])
    }

    /// Print the text rendering to stdout.
    pub fn print(&self) {
        print!("{}", self.to_text());
    }

    /// Append markdown to a report file (e.g. results/experiments.md).
    pub fn append_markdown(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        writeln!(f, "{}", self.to_markdown())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Table X", &["config", "ppl", "savings"]);
        t.row(vec![Cell::s("baseline"), Cell::f(3.021, 3), Cell::pct(0.0)]);
        t.row(vec![Cell::s("AE 4L"), Cell::f(3.444, 3), Cell::pct(0.25)]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        assert!(txt.contains("Table X"));
        assert!(txt.contains("3.021"));
        assert!(txt.contains("25.0%"));
        // aligned columns: every data line has the same length
        let lines: Vec<&str> = txt.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[1].len());
        assert_eq!(lines[1].len(), lines[2].len());
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.starts_with("### Table X"));
        // header + separator + 2 rows, 4 pipes each
        assert_eq!(md.matches('|').count(), 4 * 4);
    }

    #[test]
    fn json_roundtrip() {
        let j = sample().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.get("rows").unwrap().as_arr().unwrap().len(),
            2
        );
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec![Cell::s("only one")]);
    }
}
