//! GPU memory simulator — regenerates the paper's system evaluation
//! (Figs. 2 and 3: max sequence length vs batch size before OOM on an
//! NVIDIA A40, under 0/25/50/75% KV compression).
//!
//! The paper's measurement is pure memory arithmetic: decoding runs out of
//! device memory when weights + runtime overhead + activation workspace +
//! KV cache exceed capacity.  We model each term explicitly and solve for
//! the OOM frontier:
//!
//!   capacity >= weights + fixed + act_per_token * B * S
//!                + kv_per_token(plan) * B * S
//!
//!   max_seq(B) = (capacity - weights - fixed) / (B * (act + kv))
//!
//! Calibration (documented per DESIGN.md §3 substitution rule): `fixed`
//! covers the CUDA context + allocator slack; `act_per_token` covers the
//! transient activations/workspace the serving stack keeps per token of
//! context at peak (attention scores, hidden states).  Constants are
//! chosen once so the *baseline* GPT-2 curve lands in the paper's range;
//! the compression curves then follow from the plan arithmetic alone —
//! those are the claims under reproduction.

use crate::model::memory::{kv_bytes_per_token, CompressionPlan};
use crate::model::ModelSpec;

/// NVIDIA A40: the paper reports 44.98 GB usable.
pub const A40_BYTES: u64 = 44_980_000_000;

/// Fixed runtime overhead: CUDA context, cuBLAS workspaces, fragmentation.
pub const FIXED_OVERHEAD_BYTES: u64 = 600_000_000;

#[derive(Debug, Clone)]
/// Device memory model the frontier sweep runs against.
pub struct GpuModel {
    /// device label (e.g. "A40-48G")
    pub name: String,
    /// total device memory
    pub capacity_bytes: u64,
    /// framework/runtime overhead reserved off the top
    pub fixed_bytes: u64,
    /// transient activation/workspace bytes retained per token of context
    /// at the peak of a decode step, per sequence (scales with d_model)
    pub act_bytes_per_token: f64,
}

impl GpuModel {
    /// A40 sized for the given model: activation term scales with model
    /// width (fp16 hidden states + attention workspace; the live-layer
    /// multiplier is calibrated once per architecture family so the
    /// *baseline* curve lands in the paper's range — the compression
    /// curves then follow from plan arithmetic alone, see module docs).
    pub fn a40_for(spec: &ModelSpec) -> GpuModel {
        let live_layers = match spec.arch {
            crate::model::Arch::Gpt2 => 12,
            crate::model::Arch::Llama => 16,
        };
        GpuModel {
            name: format!("A40/{}", spec.name),
            capacity_bytes: A40_BYTES,
            fixed_bytes: FIXED_OVERHEAD_BYTES,
            act_bytes_per_token: (spec.d_model * 2 * live_layers) as f64,
        }
    }

    /// Bytes available for the KV cache + activations once weights are
    /// resident.
    pub fn dynamic_budget(&self, spec: &ModelSpec) -> u64 {
        self.capacity_bytes
            .saturating_sub(spec.weight_bytes() + spec.ae_param_count() * spec.bytes_per_el as u64)
            .saturating_sub(self.fixed_bytes)
    }

    /// Max sequence length before OOM at the given batch size and plan.
    pub fn max_seq_len(&self, spec: &ModelSpec, plan: &CompressionPlan, batch: usize) -> usize {
        let budget = self.dynamic_budget(spec) as f64;
        let per_tok = self.act_bytes_per_token + kv_bytes_per_token(spec, plan) as f64;
        let s = budget / (batch as f64 * per_tok);
        s.floor() as usize
    }

    /// Max batch size before OOM at the given sequence length.
    pub fn max_batch(&self, spec: &ModelSpec, plan: &CompressionPlan, seq_len: usize) -> usize {
        let budget = self.dynamic_budget(spec) as f64;
        let per_tok = self.act_bytes_per_token + kv_bytes_per_token(spec, plan) as f64;
        (budget / (seq_len as f64 * per_tok)).floor() as usize
    }

    /// Whether a workload fits (used by the coordinator's admission
    /// control when configured with a simulated device budget).
    pub fn fits(
        &self,
        spec: &ModelSpec,
        plan: &CompressionPlan,
        batch: usize,
        seq_len: usize,
    ) -> bool {
        let per_tok = self.act_bytes_per_token + kv_bytes_per_token(spec, plan) as f64;
        (batch as f64 * seq_len as f64 * per_tok) <= self.dynamic_budget(spec) as f64
    }
}

/// A "k% compression" plan in the figure's sense: the KV payload is
/// reduced to (1-k) of baseline, uniformly. 50% = AE-halving everywhere;
/// 75% = AE + int8-like halving again. Implemented as a fractional payload
/// so the sweep hits the exact ratios the figure labels.
#[derive(Debug, Clone, Copy)]
pub enum FigureCompression {
    /// uncompressed KV cache
    Baseline,
    /// 25% of KV bytes removed
    Pct25,
    /// half the KV bytes removed
    Pct50,
    /// 75% of KV bytes removed
    Pct75,
}

impl FigureCompression {
    /// Fraction of baseline KV bytes that remain.
    pub fn ratio(self) -> f64 {
        match self {
            FigureCompression::Baseline => 1.0,
            FigureCompression::Pct25 => 0.75,
            FigureCompression::Pct50 => 0.50,
            FigureCompression::Pct75 => 0.25,
        }
    }

    /// Figure legend label.
    pub fn label(self) -> &'static str {
        match self {
            FigureCompression::Baseline => "baseline",
            FigureCompression::Pct25 => "25% compression",
            FigureCompression::Pct50 => "50% compression",
            FigureCompression::Pct75 => "75% compression",
        }
    }

    /// Every ratio, sweep order.
    pub fn all() -> [FigureCompression; 4] {
        [
            FigureCompression::Baseline,
            FigureCompression::Pct25,
            FigureCompression::Pct50,
            FigureCompression::Pct75,
        ]
    }

    /// Concrete KV-CAR plan achieving this ratio on the given spec:
    /// 25% -> AE on half the layers; 50% -> AE everywhere; 75% -> AE
    /// everywhere + int8 on the latents (2 B/el fp16 -> ~1 B/el).
    pub fn as_plan(self, spec: &ModelSpec) -> CompressionPlan {
        match self {
            FigureCompression::Baseline => CompressionPlan::none(spec.n_layer, spec.n_kv_head),
            FigureCompression::Pct25 => CompressionPlan::ae_first_layers(spec, spec.n_layer / 2),
            FigureCompression::Pct50 => CompressionPlan::ae_first_layers(spec, spec.n_layer),
            FigureCompression::Pct75 => {
                CompressionPlan::ae_first_layers(spec, spec.n_layer).with_quant()
            }
        }
    }
}

/// One row of a Fig. 2/3 sweep.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    /// concurrent sequences
    pub batch: usize,
    /// longest context that fits at this batch
    pub max_seq: usize,
}

/// Sweep max_seq over batch sizes for one compression ratio, using an
/// idealized fractional payload (the figure's definition of "k%
/// compression") so ratios are exact.
pub fn frontier(
    gpu: &GpuModel,
    spec: &ModelSpec,
    ratio: f64,
    batches: &[usize],
) -> Vec<FrontierPoint> {
    let base = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
    let base_kv = kv_bytes_per_token(spec, &base) as f64;
    batches
        .iter()
        .map(|&b| {
            let per_tok = gpu.act_bytes_per_token + base_kv * ratio;
            let budget = gpu.dynamic_budget(spec) as f64;
            FrontierPoint {
                batch: b,
                max_seq: (budget / (b as f64 * per_tok)).floor() as usize,
            }
        })
        .collect()
}

/// Batch sizes the paper's Figs. 2-3 sweep.
pub const FIGURE_BATCHES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{gpt2_774m, tinyllama_1_1b};

    #[test]
    fn more_compression_never_hurts() {
        let spec = gpt2_774m();
        let gpu = GpuModel::a40_for(&spec);
        for b in FIGURE_BATCHES {
            let mut prev = 0;
            for c in FigureCompression::all() {
                let s = gpu.max_seq_len(&spec, &c.as_plan(&spec), b);
                assert!(s >= prev, "b={b} {c:?}");
                prev = s;
            }
        }
    }

    #[test]
    fn seq_len_decreases_with_batch() {
        let spec = tinyllama_1_1b();
        let gpu = GpuModel::a40_for(&spec);
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let mut prev = usize::MAX;
        for b in FIGURE_BATCHES {
            let s = gpu.max_seq_len(&spec, &plan, b);
            assert!(s < prev);
            prev = s;
        }
    }

    #[test]
    fn frontier_ratio_shifts_curve_up() {
        let spec = gpt2_774m();
        let gpu = GpuModel::a40_for(&spec);
        let f1 = frontier(&gpu, &spec, 1.0, &FIGURE_BATCHES);
        let f4 = frontier(&gpu, &spec, 0.25, &FIGURE_BATCHES);
        for (a, b) in f1.iter().zip(&f4) {
            assert!(b.max_seq > a.max_seq * 2, "{} vs {}", a.max_seq, b.max_seq);
        }
    }

    #[test]
    fn fits_matches_frontier() {
        let spec = gpt2_774m();
        let gpu = GpuModel::a40_for(&spec);
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let s = gpu.max_seq_len(&spec, &plan, 16);
        assert!(gpu.fits(&spec, &plan, 16, s));
        assert!(!gpu.fits(&spec, &plan, 16, s + 16));
    }

    #[test]
    fn max_batch_inverse_of_max_seq() {
        let spec = tinyllama_1_1b();
        let gpu = GpuModel::a40_for(&spec);
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let s = gpu.max_seq_len(&spec, &plan, 8);
        let b = gpu.max_batch(&spec, &plan, s);
        assert!((8..=9).contains(&b), "{b}");
    }

    #[test]
    fn paper_ballpark_gpt2_baseline() {
        // the baseline GPT-2 curve should land at a few thousand tokens at
        // B=64 (the paper's deltas imply a ~1.7-3k baseline there)
        let spec = gpt2_774m();
        let gpu = GpuModel::a40_for(&spec);
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let s = gpu.max_seq_len(&spec, &plan, 64);
        assert!((1_000..6_000).contains(&s), "{s}");
    }
}
