//! Model architecture specs.
//!
//! Two kinds of specs coexist (DESIGN.md §3):
//!
//! * **Runtime specs** (`gpt2t`, `tinyllama_t`) — the tiny trained-from-
//!   scratch models whose AOT artifacts actually execute; loaded from
//!   `artifacts/manifest.json` so rust and python can never disagree.
//! * **Paper-scale specs** (`gpt2-774m`, `tinyllama-1.1b`) — the exact
//!   dimensions of the models the paper evaluates, used by the memory
//!   simulator to regenerate Figs. 2-3 and the Eq. 3 worked example.

pub mod memory;

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

#[derive(Debug, Clone, PartialEq)]
/// Model dimensions every layer of the system sizes itself from.
pub struct ModelSpec {
    /// model id
    pub name: String,
    /// block architecture (GPT-2 or Llama style)
    pub arch: Arch,
    /// vocabulary size
    pub vocab: usize,
    /// transformer layers
    pub n_layer: usize,
    /// residual width
    pub d_model: usize,
    /// query heads
    pub n_head: usize,
    /// KV heads (GQA when < n_head)
    pub n_kv_head: usize,
    /// per-head width
    pub d_head: usize,
    /// feed-forward hidden width
    pub ffn_dim: usize,
    /// maximum context length
    pub max_seq: usize,
    /// KV-CAR autoencoder dims (kv_dim -> ae_hidden -> ae_latent)
    pub ae_hidden: usize,
    /// AE bottleneck width (the stored latent)
    pub ae_latent: usize,
    /// bytes per stored element for this deployment (4 = f32 runtime,
    /// 2 = the paper's fp16 serving assumption)
    pub bytes_per_el: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Transformer block family.
pub enum Arch {
    /// GPT-2 style (LayerNorm, learned positions, fused QKV)
    Gpt2,
    /// Llama style (RMSNorm, RoPE, gated FFN, GQA)
    Llama,
}

impl ModelSpec {
    /// Width of the K (or V) vector entering the cache per token per layer.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_head * self.d_head
    }

    /// Width of the query projection.
    pub fn q_dim(&self) -> usize {
        self.n_head * self.d_head
    }

    /// Query heads sharing one KV head (GQA group).
    pub fn group_size(&self) -> usize {
        self.n_head / self.n_kv_head
    }

    /// Approximate parameter count (embeddings tied).
    pub fn param_count(&self) -> u64 {
        let (d, f, l) = (self.d_model as u64, self.ffn_dim as u64, self.n_layer as u64);
        let (qd, kvd) = (self.q_dim() as u64, self.kv_dim() as u64);
        let attn = d * qd + 2 * d * kvd + qd * d;
        let per_layer = match self.arch {
            Arch::Gpt2 => attn + (qd + 2 * kvd + d) + 2 * d * f + f + d + 4 * d,
            Arch::Llama => attn + 3 * d * f + 2 * d,
        };
        let emb = (self.vocab as u64) * d
            + if self.arch == Arch::Gpt2 {
                (self.max_seq as u64) * d
            } else {
                0
            };
        emb + l * per_layer + d
    }

    /// Parameter bytes at this deployment's element width.
    pub fn weight_bytes(&self) -> u64 {
        self.param_count() * self.bytes_per_el as u64
    }

    /// Parameters added by the per-layer K+V autoencoders.
    pub fn ae_param_count(&self) -> u64 {
        let (kvd, h, dl) = (
            self.kv_dim() as u64,
            self.ae_hidden as u64,
            self.ae_latent as u64,
        );
        // enc: kvd*h + h + 4h + h*dl + dl ; dec mirrored ; x2 for K and V
        let enc = kvd * h + h + 4 * h + h * dl + dl;
        let dec = dl * h + h + 4 * h + h * kvd + kvd;
        2 * (enc + dec) * self.n_layer as u64
    }

    /// Parse a runtime spec from `manifest.json` (rust and python can
    /// never disagree on dimensions).
    pub fn from_manifest(man: &Json, name: &str) -> Result<ModelSpec> {
        let m = man
            .get("models")
            .and_then(|ms| ms.get(name))
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))?;
        let get = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest model '{name}' missing field '{k}'"))
        };
        let arch = match m.get("arch").and_then(Json::as_str) {
            Some("gpt2") => Arch::Gpt2,
            Some("llama") => Arch::Llama,
            other => return Err(anyhow!("unknown arch {other:?}")),
        };
        Ok(ModelSpec {
            name: name.to_string(),
            arch,
            vocab: get("vocab")?,
            n_layer: get("n_layer")?,
            d_model: get("d_model")?,
            n_head: get("n_head")?,
            n_kv_head: get("n_kv_head")?,
            d_head: get("d_head")?,
            ffn_dim: get("ffn_dim")?,
            max_seq: get("max_seq")?,
            ae_hidden: get("ae_hidden")?,
            ae_latent: get("ae_latent")?,
            bytes_per_el: 4, // runtime artifacts are f32
        })
    }
}

/// GPT-2 774M (GPT-2 Large), as evaluated in the paper (fp16 serving).
pub fn gpt2_774m() -> ModelSpec {
    ModelSpec {
        name: "gpt2-774m".into(),
        arch: Arch::Gpt2,
        vocab: 50257,
        n_layer: 36,
        d_model: 1280,
        n_head: 20,
        n_kv_head: 20,
        d_head: 64,
        ffn_dim: 5120,
        max_seq: 1024,
        ae_hidden: 256, // "lightweight" (paper §I): AE params ~9% of model
        ae_latent: 640, // paper's factor-of-two embedding compression
        bytes_per_el: 2,
    }
}

/// TinyLlama 1.1B, as evaluated in the paper (fp16 serving, GQA 32q/4kv).
pub fn tinyllama_1_1b() -> ModelSpec {
    ModelSpec {
        name: "tinyllama-1.1b".into(),
        arch: Arch::Llama,
        vocab: 32000,
        n_layer: 22,
        d_model: 2048,
        n_head: 32,
        n_kv_head: 4,
        d_head: 64,
        ffn_dim: 5632,
        max_seq: 2048,
        ae_hidden: 192,
        ae_latent: 128,
        bytes_per_el: 2,
    }
}

/// GPT-2 Medium — the paper's §II-B worked example for Eq. 3.
pub fn gpt2_medium() -> ModelSpec {
    ModelSpec {
        name: "gpt2-medium".into(),
        arch: Arch::Gpt2,
        vocab: 50257,
        n_layer: 24,
        d_model: 1024,
        n_head: 16,
        n_kv_head: 16,
        d_head: 64,
        ffn_dim: 4096,
        max_seq: 1024,
        ae_hidden: 768,
        ae_latent: 512,
        bytes_per_el: 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_param_counts() {
        // within 6% of the advertised sizes
        let g = gpt2_774m().param_count() as f64;
        assert!((g - 774e6).abs() / 774e6 < 0.06, "{g}");
        let t = tinyllama_1_1b().param_count() as f64;
        assert!((t - 1.1e9).abs() / 1.1e9 < 0.06, "{t}");
    }

    #[test]
    fn kv_dims() {
        assert_eq!(gpt2_774m().kv_dim(), 1280);
        assert_eq!(tinyllama_1_1b().kv_dim(), 256); // GQA shrinks the cache
        assert_eq!(tinyllama_1_1b().group_size(), 8);
    }

    #[test]
    fn ae_params_are_small_relative_to_model() {
        let s = gpt2_774m();
        let frac = s.ae_param_count() as f64 / s.param_count() as f64;
        assert!(frac < 0.25, "autoencoders must stay lightweight: {frac}");
    }
}
