//! KV-cache memory accounting — Eq. 3 of the paper, generalized to
//! per-layer / per-head compression plans.
//!
//!   KV_Cache_Size = 2 * P * N_layers * d_kv * L_seq * B            (Eq. 3)
//!
//! With KV-CAR the per-token-per-layer payload is no longer uniform:
//! AE-compressed layers store `ae_latent` floats per K (and V) vector,
//! reused heads store nothing (they alias the previous layer's block),
//! and int8 quantization shrinks each stored element to one byte plus a
//! per-vector (scale, zeropoint) header.  `plan_*` functions compute the
//! exact footprint the rust cache manager will measure at runtime — the
//! two are cross-checked in kvcache tests.

use super::ModelSpec;

/// Which compression mechanisms apply where. Mirrors the runtime masks the
/// AOT artifacts take (compress [L], reuse_k/v [L][Hkv], quant flag).
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionPlan {
    /// per-layer: K/V vectors stored as `ae_latent` latents
    pub ae_layers: Vec<bool>,
    /// per-(layer, kv-head): K head aliases layer l-1's stored K
    pub reuse_k: Vec<Vec<bool>>,
    /// per-(layer, kv-head): V head aliases layer l-1's stored V
    pub reuse_v: Vec<Vec<bool>>,
    /// int8 (Eq. 4) storage of whatever is stored
    pub quant_int8: bool,
}

/// Per-vector header bytes when int8 quantized: f32 scale + f32
/// zeropoint.  Re-exported from the packing codec so the analytical
/// model can never drift from the bytes the block store actually writes.
pub use crate::compress::quant::QUANT_HEADER_BYTES;

impl CompressionPlan {
    /// Uncompressed baseline plan.
    pub fn none(n_layer: usize, n_kv_head: usize) -> Self {
        CompressionPlan {
            ae_layers: vec![false; n_layer],
            reuse_k: vec![vec![false; n_kv_head]; n_layer],
            reuse_v: vec![vec![false; n_kv_head]; n_layer],
            quant_int8: false,
        }
    }

    /// AE on the first `k` layers (the paper's "compressed (k layers)")
    pub fn ae_first_layers(spec: &ModelSpec, k: usize) -> Self {
        let mut p = Self::none(spec.n_layer, spec.n_kv_head);
        for l in 0..k.min(spec.n_layer) {
            p.ae_layers[l] = true;
        }
        p
    }

    /// Stack Eq. 4 int8 on top of this plan.
    pub fn with_quant(mut self) -> Self {
        self.quant_int8 = true;
        self
    }

    /// Random valid plan spanning every store kind — full-alias layers,
    /// scattered head reuse, AE layers, int8 — for test/bench plan-space
    /// sampling (defined once so every suite samples the same space).
    pub fn random(rng: &mut crate::util::rng::Rng, n_layer: usize, n_kv_head: usize) -> Self {
        let mut plan = Self::none(n_layer, n_kv_head);
        for l in 0..n_layer {
            plan.ae_layers[l] = rng.bool(0.4);
            if l > 0 {
                if rng.bool(0.2) {
                    plan.reuse_k[l] = vec![true; n_kv_head];
                    plan.reuse_v[l] = vec![true; n_kv_head];
                } else {
                    for h in 0..n_kv_head {
                        plan.reuse_k[l][h] = rng.bool(0.25);
                        plan.reuse_v[l][h] = rng.bool(0.25);
                    }
                }
            }
        }
        plan.quant_int8 = rng.bool(0.5);
        plan
    }

    /// Validity: layer 0 can never reuse (there is no layer -1).
    pub fn validate(&self) -> Result<(), String> {
        if self.reuse_k[0].iter().any(|&r| r) || self.reuse_v[0].iter().any(|&r| r) {
            return Err("layer 0 cannot reuse heads".into());
        }
        let l = self.ae_layers.len();
        if self.reuse_k.len() != l || self.reuse_v.len() != l {
            return Err("mask length mismatch".into());
        }
        Ok(())
    }

    /// Total reused (layer, head) pairs across K and V.
    pub fn n_reused_heads(&self) -> usize {
        self.reuse_k
            .iter()
            .chain(self.reuse_v.iter())
            .flatten()
            .filter(|&&r| r)
            .count()
    }

    /// Layers with the AE round-trip enabled.
    pub fn n_ae_layers(&self) -> usize {
        self.ae_layers.iter().filter(|&&a| a).count()
    }
}

/// Stored bytes for one token's K *or* V at one layer under the plan.
///
/// Rules (matching `kvcache::manager` exactly):
/// * all heads reused        -> 0 bytes (full alias)
/// * AE layer                -> ae_latent elements (latent covers the whole
///                              kv vector; per-head granularity is lost, so
///                              partially-reused AE layers still store the
///                              full latent — reuse only pays on non-AE
///                              layers, which the planner accounts for)
/// * else                    -> (n_kv_head - reused) * d_head elements
/// * int8                    -> 1 byte/element + QUANT_HEADER_BYTES
pub fn stored_bytes_one(
    spec: &ModelSpec,
    plan: &CompressionPlan,
    layer: usize,
    reuse_row: &[bool],
) -> usize {
    let reused = reuse_row.iter().filter(|&&r| r).count();
    let elements = if reused == spec.n_kv_head {
        return 0;
    } else if plan.ae_layers[layer] {
        spec.ae_latent
    } else {
        (spec.n_kv_head - reused) * spec.d_head
    };
    if plan.quant_int8 {
        elements + QUANT_HEADER_BYTES
    } else {
        elements * spec.bytes_per_el
    }
}

/// Total stored bytes for one token across all layers (K + V).
pub fn kv_bytes_per_token(spec: &ModelSpec, plan: &CompressionPlan) -> usize {
    (0..spec.n_layer)
        .map(|l| {
            stored_bytes_one(spec, plan, l, &plan.reuse_k[l])
                + stored_bytes_one(spec, plan, l, &plan.reuse_v[l])
        })
        .sum()
}

/// Baseline Eq. 3 bytes per token (no compression).
pub fn baseline_bytes_per_token(spec: &ModelSpec) -> usize {
    2 * spec.bytes_per_el * spec.n_layer * spec.kv_dim()
}

/// Eq. 3, full cache: per-token bytes * L_seq * B.
pub fn kv_cache_bytes(
    spec: &ModelSpec,
    plan: &CompressionPlan,
    seq_len: usize,
    batch: usize,
) -> u64 {
    kv_bytes_per_token(spec, plan) as u64 * seq_len as u64 * batch as u64
}

/// Fractional savings vs the uncompressed cache (the paper's "Memory
/// Savings" column).
pub fn plan_savings(spec: &ModelSpec, plan: &CompressionPlan) -> f64 {
    1.0 - kv_bytes_per_token(spec, plan) as f64 / baseline_bytes_per_token(spec) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{gpt2_774m, gpt2_medium};

    #[test]
    fn eq3_worked_example() {
        // paper §II-B: GPT-2 Medium, fp16, L=2048, B=8 -> ~1.61 GB
        let spec = gpt2_medium();
        let plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        let bytes = kv_cache_bytes(&spec, &plan, 2048, 8);
        let gb = bytes as f64 / 1e9;
        assert!((gb - 1.61).abs() < 0.02, "{gb}");
    }

    #[test]
    fn ae_half_on_all_layers_saves_half() {
        let spec = gpt2_774m();
        let plan = CompressionPlan::ae_first_layers(&spec, spec.n_layer);
        let s = plan_savings(&spec, &plan);
        assert!((s - 0.5).abs() < 1e-9, "{s}");
    }

    #[test]
    fn ae_k_of_l_layers_scales_linearly() {
        let spec = gpt2_774m();
        for k in [0, 9, 18, 36] {
            let plan = CompressionPlan::ae_first_layers(&spec, k);
            let want = 0.5 * k as f64 / 36.0;
            assert!((plan_savings(&spec, &plan) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn full_reuse_of_alternating_layers_halves() {
        // paper: "replacing all the key and value heads between consecutive
        // layers could halve the KV cache"
        let spec = gpt2_774m();
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        for l in (1..spec.n_layer).step_by(2) {
            plan.reuse_k[l] = vec![true; spec.n_kv_head];
            plan.reuse_v[l] = vec![true; spec.n_kv_head];
        }
        let s = plan_savings(&spec, &plan);
        assert!((s - 0.5).abs() < 1e-9, "{s}");
    }

    #[test]
    fn all_key_reuse_saves_quarter() {
        // Table III row "all key": 25%
        let spec = gpt2_774m();
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        for l in (1..spec.n_layer).step_by(2) {
            plan.reuse_k[l] = vec![true; spec.n_kv_head];
        }
        assert!((plan_savings(&spec, &plan) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn per_head_reuse_accounting() {
        let spec = gpt2_774m(); // 20 kv heads
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        plan.reuse_k[3][0] = true; // one K head of one layer
        let per_head = spec.d_head * spec.bytes_per_el;
        let delta = baseline_bytes_per_token(&spec) - kv_bytes_per_token(&spec, &plan);
        assert_eq!(delta, per_head);
    }

    #[test]
    fn quant_int8_shrinks_storage() {
        let spec = gpt2_774m();
        let base = CompressionPlan::ae_first_layers(&spec, 10);
        let q = CompressionPlan::ae_first_layers(&spec, 10).with_quant();
        assert!(kv_bytes_per_token(&spec, &q) < kv_bytes_per_token(&spec, &base));
    }

    #[test]
    fn layer0_reuse_rejected() {
        let spec = gpt2_774m();
        let mut plan = CompressionPlan::none(spec.n_layer, spec.n_kv_head);
        plan.reuse_k[0][0] = true;
        assert!(plan.validate().is_err());
    }
}
